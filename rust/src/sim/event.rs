//! Discrete-event machinery: event kinds and the time-ordered scheduler.
//!
//! The scheduler is a **calendar queue** (single-level timing wheel with
//! an overflow heap): sim events are extremely time-local — decode
//! iterations, KVC transfers and arrivals land within milliseconds of
//! `now` — so hashing each event into a fixed ring of ~1 ms buckets makes
//! `push`/`pop` O(1) instead of the `BinaryHeap`'s O(log n) compare
//! cascade. Events beyond the wheel's horizon (fault firings, instance
//! startups) wait in a small overflow heap and migrate into the wheel as
//! the cursor approaches. The exact `(time, rank, seq)` total order of
//! the old heap is preserved bit-for-bit; see `docs/performance.md`.

use crate::workload::RequestId;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::fmt;

/// Generation-tagged handle into the cluster's instance slab.
///
/// `slot` indexes the slab; `seq` is a cluster-global monotonic spawn
/// sequence number. A freed slot's next occupant gets a fresh `seq`, so a
/// stale id held by an in-flight event or a router decision resolves to
/// `None` instead of aliasing the new occupant. `seq` leads the derived
/// ordering, so id-based tie-breaking (router min-by keys, retirement
/// candidate sorts) picks the oldest instance by spawn order — exactly the
/// semantics of the pre-slab monotonic ids, even after slot reuse.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct InstanceId {
    seq: u64,
    slot: u32,
}

impl InstanceId {
    pub fn new(slot: u32, seq: u64) -> InstanceId {
        InstanceId { seq, slot }
    }

    pub fn slot(self) -> usize {
        self.slot as usize
    }

    /// Global spawn sequence number (unique per spawned instance).
    pub fn seq(self) -> u64 {
        self.seq
    }
}

impl fmt::Display for InstanceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "i{}.{}", self.slot, self.seq)
    }
}

/// Everything that can happen in the simulated cluster.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// The next pending arrival from the streaming source reaches the
    /// gateway (the engine holds the request itself and schedules one
    /// arrival event at a time).
    Arrival,
    /// Periodic control-plane tick: autoscaling + queue re-evaluation.
    ControlTick,
    /// A prefiller finished the prefill of `req`.
    PrefillDone {
        instance: InstanceId,
        req: RequestId,
    },
    /// KVC transfer of `req` into decoder `instance` completed.
    TransferDone {
        instance: InstanceId,
        req: RequestId,
    },
    /// A decoder engine iteration completed.
    DecodeIterDone { instance: InstanceId, epoch: u64 },
    /// A newly provisioned instance finished starting up.
    InstanceReady { instance: InstanceId },
    /// Metrics sampling tick (time-series capture).
    SampleTick,
    /// Telemetry timeline tick (obs subsystem cluster-state capture).
    /// Never scheduled when `SimConfig::observe` is off, so observe-off
    /// runs carry zero obs events.
    ObsTick,
    /// An armed fault fires (`firing` indexes the engine's materialized
    /// firing list, which is a pure function of `SimConfig::faults`).
    Fault { firing: usize },
    /// Preemption drain deadline: the instance loses whatever work it
    /// has not finished. Stale ids (already drained and swept) no-op.
    FaultKill { instance: InstanceId },
    /// End of a degradation window: restore the instance's perf factor.
    FaultRestore { instance: InstanceId },
}

/// Scheduled entry ordered by (time, class rank, seq): simultaneous
/// events pop arrivals first, then FIFO.
///
/// The arrival-first rank preserves the pre-streaming engine's tie
/// semantics: when every arrival was preloaded at init, an arrival
/// coinciding exactly with a control/sample tick (common with replay
/// files carrying coarse, tick-aligned timestamps) always carried a lower
/// insertion seq and popped first. With arrivals now scheduled
/// just-in-time their seqs are late, so the rank makes the old ordering
/// explicit instead of an accident of preloading.
#[derive(Clone, Debug)]
struct Scheduled {
    time: f64,
    rank: u8,
    seq: u64,
    event: Event,
}

impl Scheduled {
    /// Strict `(time, rank, seq)` pop order. Times are finite (`push`
    /// rejects non-finite), and seqs are unique, so this is total.
    #[inline]
    fn before(&self, other: &Scheduled) -> bool {
        match self.time.partial_cmp(&other.time) {
            Some(Ordering::Less) => true,
            Some(Ordering::Greater) => false,
            _ => (self.rank, self.seq) < (other.rank, other.seq),
        }
    }
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.rank.cmp(&self.rank))
            .then(other.seq.cmp(&self.seq))
    }
}

/// Wheel geometry. A tick is the bucket quantum; the near wheel covers
/// `NBUCKETS` consecutive ticks (`4096 × 1/1024 s = 4 s`), which spans
/// the inter-event gaps of everything hot (decode iterations, transfers,
/// arrivals, control/sample ticks). Startup completions and fault
/// firings land in the overflow heap and migrate in lazily.
const TICKS_PER_S: f64 = 1024.0;
const NBUCKETS: usize = 4096;
const MASK: usize = NBUCKETS - 1;
/// Occupancy bitmap: one bit per bucket, one u64 word per 64 buckets.
const WORDS: usize = NBUCKETS / 64;

/// Earliest-first event queue with deterministic FIFO tie-breaking.
///
/// Calendar-queue layout:
/// - **near wheel** — `NBUCKETS` unordered `Vec` buckets indexed by
///   `tick & MASK`, holding every event whose tick falls in
///   `[cursor, cursor + NBUCKETS)`. Within that window each residue maps
///   to exactly one tick, so a bucket never mixes ticks and the first
///   occupied bucket at/after the cursor holds the earliest event.
/// - **occupancy bitmap** — one bit per bucket; the cursor scan is a
///   word-at-a-time `trailing_zeros` walk, not a bucket-by-bucket probe.
/// - **far heap** — events at/beyond `cursor + NBUCKETS`, kept in the old
///   `BinaryHeap` order and migrated into the wheel once the cursor's
///   window reaches them.
///
/// Determinism: pop order is the strict total order `(time, rank, seq)`
/// — identical to the previous `BinaryHeap` implementation, which the
/// heap-oracle property test (below) and the snapshot-equivalence suite
/// pin down.
#[derive(Debug)]
pub struct EventQueue {
    buckets: Vec<Vec<Scheduled>>,
    /// Capacity pool: the backing `Vec`s of drained buckets. A freshly
    /// occupied bucket takes one instead of growing a new allocation, so
    /// live heap capacity tracks the number of *concurrently* occupied
    /// buckets (a handful) rather than every residue the cursor has ever
    /// visited (up to all `NBUCKETS` of them on long horizons).
    free: Vec<Vec<Scheduled>>,
    occupied: [u64; WORDS],
    /// Tick of the last popped event: nothing earlier remains anywhere.
    cursor: u64,
    /// Entry count in the near wheel (buckets).
    near_len: usize,
    far: BinaryHeap<Scheduled>,
    len: usize,
    seq: u64,
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue {
            buckets: (0..NBUCKETS).map(|_| Vec::new()).collect(),
            free: Vec::new(),
            occupied: [0; WORDS],
            cursor: 0,
            near_len: 0,
            far: BinaryHeap::new(),
            len: 0,
            seq: 0,
        }
    }

    #[inline]
    fn tick_of(time: f64) -> u64 {
        // `as` truncates toward zero == floor for the clamped non-negative
        // value, and saturates at u64::MAX for out-of-range input.
        (time.max(0.0) * TICKS_PER_S) as u64
    }

    pub fn push(&mut self, time: f64, event: Event) {
        // A NaN/∞ time would break the strict `(time, rank, seq)` total
        // order and silently corrupt pop order downstream; fail loudly in
        // release builds too (satellite of the scheduler swap).
        assert!(
            time.is_finite(),
            "EventQueue::push: non-finite event time {time} for {event:?}"
        );
        let rank = u8::from(!matches!(event, Event::Arrival));
        let s = Scheduled {
            time,
            rank,
            seq: self.seq,
            event,
        };
        self.seq += 1;
        self.insert(s);
    }

    /// Seat `s` in near-wheel bucket `b`, reusing a pooled allocation
    /// when the bucket's own `Vec` was handed to the pool on drain.
    #[inline]
    fn bucket_push(&mut self, b: usize, s: Scheduled) {
        let bucket = &mut self.buckets[b];
        if bucket.capacity() == 0 {
            if let Some(pooled) = self.free.pop() {
                self.buckets[b] = pooled;
            }
        }
        self.buckets[b].push(s);
        self.occupied[b >> 6] |= 1 << (b & 63);
        self.near_len += 1;
    }

    fn insert(&mut self, s: Scheduled) {
        // The engine never schedules into the past; clamp defensively so
        // a same-tick float edge still lands in a scannable bucket (the
        // in-bucket min is by exact `(time, rank, seq)`, so placement
        // never affects pop order, only scan efficiency).
        let tick = Self::tick_of(s.time).max(self.cursor);
        if tick < self.cursor + NBUCKETS as u64 {
            self.bucket_push((tick as usize) & MASK, s);
        } else {
            self.far.push(s);
        }
        self.len += 1;
    }

    /// Move far-heap entries whose tick now falls inside the near window
    /// into their buckets. Called with the cursor settled for this pop.
    fn migrate(&mut self) {
        let horizon = self.cursor + NBUCKETS as u64;
        while let Some(head) = self.far.peek() {
            if Self::tick_of(head.time) >= horizon {
                break;
            }
            let s = self.far.pop().expect("peeked entry exists");
            let b = (Self::tick_of(s.time).max(self.cursor) as usize) & MASK;
            self.bucket_push(b, s);
        }
    }

    /// Tick of the first occupied bucket at/after the cursor, scanning
    /// the bitmap circularly (the near window is one full revolution).
    fn next_occupied_tick(&self) -> Option<u64> {
        if self.near_len == 0 {
            return None;
        }
        let b0 = (self.cursor as usize) & MASK;
        let (w0, bit0) = (b0 >> 6, b0 & 63);
        let head = self.occupied[w0] & (!0u64 << bit0);
        if head != 0 {
            let b = (w0 << 6) | head.trailing_zeros() as usize;
            return Some(self.cursor + ((b + NBUCKETS - b0) & MASK) as u64);
        }
        for k in 1..=WORDS {
            let wi = (w0 + k) & (WORDS - 1);
            let mut w = self.occupied[wi];
            if k == WORDS {
                // Wrapped back into the cursor's word: only the buckets
                // *before* the cursor (end of the revolution) remain.
                w &= !(!0u64 << bit0);
            }
            if w != 0 {
                let b = (wi << 6) | w.trailing_zeros() as usize;
                return Some(self.cursor + ((b + NBUCKETS - b0) & MASK) as u64);
            }
        }
        None
    }

    pub fn pop(&mut self) -> Option<(f64, Event)> {
        if self.len == 0 {
            return None;
        }
        if self.near_len == 0 {
            // Idle stretch: jump the cursor straight to the earliest far
            // event instead of sweeping empty revolutions.
            let head = self.far.peek().expect("len > 0 with empty wheel");
            self.cursor = self.cursor.max(Self::tick_of(head.time));
        }
        self.migrate();
        let tick = self
            .next_occupied_tick()
            .expect("near wheel holds the minimum after migration");
        self.cursor = tick;
        let b = (tick as usize) & MASK;
        let bucket = &mut self.buckets[b];
        let mut mi = 0;
        for i in 1..bucket.len() {
            if bucket[i].before(&bucket[mi]) {
                mi = i;
            }
        }
        let s = bucket.swap_remove(mi);
        if bucket.is_empty() {
            self.occupied[b >> 6] &= !(1 << (b & 63));
            // Hand the drained bucket's allocation to the pool; the next
            // bucket to become occupied reuses it (see `bucket_push`).
            let pooled = std::mem::take(bucket);
            if pooled.capacity() > 0 {
                self.free.push(pooled);
            }
        }
        self.near_len -= 1;
        self.len -= 1;
        Some((s.time, s.event))
    }

    pub fn peek_time(&self) -> Option<f64> {
        if self.len == 0 {
            return None;
        }
        // Earliest time overall = min(first occupied near bucket's min
        // time, far-heap head time). `time` leads the total order, so the
        // rank/seq tie-break cannot change which *time* comes first.
        let near = self.next_occupied_tick().map(|tick| {
            let bucket = &self.buckets[(tick as usize) & MASK];
            bucket
                .iter()
                .map(|s| s.time)
                .fold(f64::INFINITY, f64::min)
        });
        let far = self.far.peek().map(|s| s.time);
        match (near, far) {
            (Some(n), Some(f)) => Some(n.min(f)),
            (Some(n), None) => Some(n),
            (None, f) => f,
        }
    }

    /// Capture the full queue state for a checkpoint: every scheduled
    /// entry as `(time, rank, seq, event)` sorted in pop order, plus the
    /// next insertion sequence number. `(time, rank, seq)` is a strict
    /// total order (seqs are unique), so the sorted dump plus preserved
    /// seqs reproduces the exact pop sequence on rebuild — regardless of
    /// how entries were split between the near wheel and the far heap.
    pub fn dump(&self) -> (Vec<(f64, u8, u64, Event)>, u64) {
        let mut entries: Vec<&Scheduled> = self
            .buckets
            .iter()
            .flatten()
            .chain(self.far.iter())
            .collect();
        entries.sort_by(|a, b| b.cmp(a)); // Ord is inverted for the max-heap
        (
            entries
                .into_iter()
                .map(|s| (s.time, s.rank, s.seq, s.event.clone()))
                .collect(),
            self.seq,
        )
    }

    /// Rebuild a queue from a [`EventQueue::dump`]: entries keep their
    /// original seqs (tie-break order) and future pushes continue from
    /// `next_seq`.
    pub fn rebuild(entries: Vec<(f64, u8, u64, Event)>, next_seq: u64) -> EventQueue {
        let mut q = EventQueue::new();
        // Seat the cursor at the earliest entry so the near window lands
        // where the resumed sim actually is (t=0 would bucket everything
        // into the far heap and force a pointless first migration).
        q.cursor = entries
            .iter()
            .map(|e| Self::tick_of(e.0))
            .min()
            .unwrap_or(0);
        for (time, rank, seq, event) in entries {
            q.insert(Scheduled {
                time,
                rank,
                seq,
                event,
            });
        }
        q.seq = next_seq;
        q
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, Event::ControlTick);
        q.push(1.0, Event::Arrival);
        q.push(2.0, Event::SampleTick);
        assert_eq!(q.pop().unwrap().0, 1.0);
        assert_eq!(q.pop().unwrap().0, 2.0);
        assert_eq!(q.pop().unwrap().0, 3.0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_are_fifo() {
        let ev = |req: u64| Event::PrefillDone {
            instance: InstanceId::new(0, 0),
            req,
        };
        let mut q = EventQueue::new();
        q.push(1.0, ev(1));
        q.push(1.0, ev(2));
        q.push(1.0, ev(3));
        let order: Vec<Event> = (0..3).map(|_| q.pop().unwrap().1).collect();
        assert_eq!(order, vec![ev(1), ev(2), ev(3)]);
    }

    #[test]
    fn dump_and_rebuild_preserve_pop_order_and_ties() {
        let ev = |req: u64| Event::PrefillDone {
            instance: InstanceId::new(0, 0),
            req,
        };
        let mut q = EventQueue::new();
        q.push(2.0, ev(1));
        q.push(1.0, Event::ControlTick);
        q.push(1.0, Event::Arrival); // later push, earlier rank
        q.push(2.0, ev(2)); // FIFO tie with ev(1)
        let (entries, seq) = q.dump();
        assert_eq!(entries.len(), 4);
        // Dump is in pop order: arrival first at t=1.
        assert_eq!(entries[0].3, Event::Arrival);
        let mut rebuilt = EventQueue::rebuild(entries, seq);
        let mut order = Vec::new();
        while let Some((t, e)) = rebuilt.pop() {
            order.push((t, e));
            if let Some((qt, qe)) = q.pop() {
                assert_eq!(order.last().unwrap(), &(qt, qe));
            }
        }
        assert_eq!(order.len(), 4);
        assert_eq!(order[2].1, ev(1));
        assert_eq!(order[3].1, ev(2));
    }

    #[test]
    fn arrival_wins_exact_time_ties() {
        // A just-in-time-scheduled arrival coinciding with an earlier-
        // pushed tick must still pop first (pre-streaming semantics).
        let mut q = EventQueue::new();
        q.push(1.0, Event::ControlTick);
        q.push(1.0, Event::SampleTick);
        q.push(1.0, Event::Arrival);
        assert_eq!(q.pop().unwrap().1, Event::Arrival);
        assert_eq!(q.pop().unwrap().1, Event::ControlTick);
        assert_eq!(q.pop().unwrap().1, Event::SampleTick);
    }

    #[test]
    fn far_horizon_events_migrate_in_order() {
        // Events far beyond the wheel's 4 s coverage (fault firings,
        // week-scale horizons) live in the overflow heap until the cursor
        // approaches; pop order must be seamless across the boundary.
        let mut q = EventQueue::new();
        q.push(9000.0, Event::ControlTick);
        q.push(0.5, Event::SampleTick);
        q.push(100.0, Event::Arrival);
        q.push(100.0, Event::ControlTick);
        q.push(8999.9, Event::SampleTick);
        let times: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|(t, _)| t).collect();
        assert_eq!(times, vec![0.5, 100.0, 100.0, 8999.9, 9000.0]);
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn non_finite_push_panics_in_release_too() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, Event::ControlTick);
    }

    /// The old `BinaryHeap` scheduler, kept verbatim as the ordering
    /// oracle for the property test below.
    struct OracleQueue {
        heap: BinaryHeap<Scheduled>,
        seq: u64,
    }

    impl OracleQueue {
        fn new() -> Self {
            OracleQueue {
                heap: BinaryHeap::new(),
                seq: 0,
            }
        }

        fn push(&mut self, time: f64, event: Event) {
            let rank = u8::from(!matches!(event, Event::Arrival));
            self.heap.push(Scheduled {
                time,
                rank,
                seq: self.seq,
                event,
            });
            self.seq += 1;
        }

        fn pop(&mut self) -> Option<(f64, Event)> {
            self.heap.pop().map(|s| (s.time, s.event))
        }
    }

    #[test]
    fn prop_wheel_matches_heap_oracle() {
        check(Config::named("wheel-vs-heap").cases(96), |rng| {
            let mut wheel = EventQueue::new();
            let mut oracle = OracleQueue::new();
            // Quantized times produce dense exact-tie clusters; the wide
            // span (0..~40 s at ops≈200) exercises near and far wheels.
            let quantum = [0.25, 0.001, 7.5][rng.below(3) as usize];
            let ops = 40 + rng.below(200) as usize;
            let mut now = 0.0f64;
            let event = |rng: &mut crate::util::rng::Pcg64| match rng.below(4) {
                0 => Event::Arrival,
                1 => Event::ControlTick,
                2 => Event::SampleTick,
                _ => Event::PrefillDone {
                    instance: InstanceId::new(0, 0),
                    req: rng.below(8),
                },
            };
            for _ in 0..ops {
                if rng.chance(0.6) || wheel.is_empty() {
                    // Push 1–4 events at/after `now`, snapped to the
                    // quantum so exact ties across ranks are common.
                    for _ in 0..=rng.below(3) {
                        let steps = rng.below(64) as f64;
                        let t = now + steps * quantum;
                        let e = event(rng);
                        wheel.push(t, e.clone());
                        oracle.push(t, e);
                    }
                } else {
                    let got = wheel.pop();
                    let want = oracle.pop();
                    assert_eq!(got, want, "pop diverged from heap oracle");
                    if let Some((t, _)) = got {
                        now = t;
                    }
                }
                if rng.chance(0.05) {
                    // Mid-stream checkpoint: dump/rebuild must preserve
                    // the remaining pop sequence exactly.
                    let (entries, seq) = wheel.dump();
                    wheel = EventQueue::rebuild(entries, seq);
                }
            }
            // Drain both: full remaining sequences must match.
            loop {
                let got = wheel.pop();
                let want = oracle.pop();
                assert_eq!(got, want, "drain diverged from heap oracle");
                if got.is_none() {
                    break;
                }
            }
        });
    }

    #[test]
    fn drained_buckets_recycle_their_allocations() {
        let mut q = EventQueue::new();
        for _ in 0..32 {
            q.push(0.5, Event::ControlTick);
        }
        while q.pop().is_some() {}
        // The drained bucket's Vec (grown to hold 32 entries) is pooled...
        assert!(q.free.iter().any(|v| v.capacity() >= 32));
        let pooled = q.free.len();
        // ...and the next bucket to become occupied takes it instead of
        // growing a fresh allocation.
        q.push(1.0, Event::SampleTick);
        assert_eq!(q.free.len(), pooled - 1);
        let b = (EventQueue::tick_of(1.0) as usize) & MASK;
        assert!(q.buckets[b].capacity() >= 32, "pooled capacity reused");
        assert_eq!(q.pop().unwrap().1, Event::SampleTick);
    }

    #[test]
    fn empty_queue_dump_rebuilds_and_continues_seqs() {
        let mut q = EventQueue::new();
        q.push(1.0, Event::Arrival);
        assert!(q.pop().is_some());
        let (entries, seq) = q.dump();
        assert!(entries.is_empty());
        assert_eq!(seq, 1);
        let mut rebuilt = EventQueue::rebuild(entries, seq);
        assert!(rebuilt.is_empty());
        assert_eq!(rebuilt.pop(), None);
        // Future pushes continue the seq stream past the checkpoint, so
        // FIFO tie-breaks stay aligned with the uncheckpointed run.
        rebuilt.push(2.0, Event::ControlTick);
        assert_eq!(rebuilt.seq, seq + 1);
        assert_eq!(rebuilt.pop().unwrap().0, 2.0);
    }

    #[test]
    fn idle_cursor_jump_migrates_far_events_with_ties_intact() {
        let mut q = EventQueue::new();
        q.push(0.1, Event::Arrival);
        q.push(10_000.0, Event::ControlTick); // far beyond the 4 s window
        q.push(10_000.0, Event::SampleTick); // far, exact FIFO tie
        assert_eq!(q.pop().unwrap().1, Event::Arrival);
        assert_eq!(q.far.len(), 2, "distant events wait in the overflow heap");
        assert_eq!(q.near_len, 0);
        // The next pop jumps the cursor across the ~10,000 s idle gap;
        // both far events migrate into the wheel and the FIFO tie pops
        // in push order.
        assert_eq!(q.pop().unwrap().1, Event::ControlTick);
        assert!(q.far.is_empty(), "migration drains the overflow heap");
        assert_eq!(q.near_len, 1);
        assert_eq!(q.pop().unwrap().1, Event::SampleTick);
        assert!(q.is_empty());
    }

    #[test]
    fn peek_time_tracks_global_minimum() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.push(50.0, Event::ControlTick); // far
        assert_eq!(q.peek_time(), Some(50.0));
        q.push(0.25, Event::SampleTick); // near
        assert_eq!(q.peek_time(), Some(0.25));
        q.pop();
        assert_eq!(q.peek_time(), Some(50.0));
    }
}
