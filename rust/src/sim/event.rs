//! Discrete-event machinery: event kinds and the time-ordered event heap.

use crate::workload::RequestId;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::fmt;

/// Generation-tagged handle into the cluster's instance slab.
///
/// `slot` indexes the slab; `seq` is a cluster-global monotonic spawn
/// sequence number. A freed slot's next occupant gets a fresh `seq`, so a
/// stale id held by an in-flight event or a router decision resolves to
/// `None` instead of aliasing the new occupant. `seq` leads the derived
/// ordering, so id-based tie-breaking (router min-by keys, retirement
/// candidate sorts) picks the oldest instance by spawn order — exactly the
/// semantics of the pre-slab monotonic ids, even after slot reuse.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct InstanceId {
    seq: u64,
    slot: u32,
}

impl InstanceId {
    pub fn new(slot: u32, seq: u64) -> InstanceId {
        InstanceId { seq, slot }
    }

    pub fn slot(self) -> usize {
        self.slot as usize
    }

    /// Global spawn sequence number (unique per spawned instance).
    pub fn seq(self) -> u64 {
        self.seq
    }
}

impl fmt::Display for InstanceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "i{}.{}", self.slot, self.seq)
    }
}

/// Everything that can happen in the simulated cluster.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// The next pending arrival from the streaming source reaches the
    /// gateway (the engine holds the request itself and schedules one
    /// arrival event at a time).
    Arrival,
    /// Periodic control-plane tick: autoscaling + queue re-evaluation.
    ControlTick,
    /// A prefiller finished the prefill of `req`.
    PrefillDone {
        instance: InstanceId,
        req: RequestId,
    },
    /// KVC transfer of `req` into decoder `instance` completed.
    TransferDone {
        instance: InstanceId,
        req: RequestId,
    },
    /// A decoder engine iteration completed.
    DecodeIterDone { instance: InstanceId, epoch: u64 },
    /// A newly provisioned instance finished starting up.
    InstanceReady { instance: InstanceId },
    /// Metrics sampling tick (time-series capture).
    SampleTick,
    /// An armed fault fires (`firing` indexes the engine's materialized
    /// firing list, which is a pure function of `SimConfig::faults`).
    Fault { firing: usize },
    /// Preemption drain deadline: the instance loses whatever work it
    /// has not finished. Stale ids (already drained and swept) no-op.
    FaultKill { instance: InstanceId },
    /// End of a degradation window: restore the instance's perf factor.
    FaultRestore { instance: InstanceId },
}

/// Heap entry ordered by (time, class rank, seq): simultaneous events pop
/// arrivals first, then FIFO.
///
/// The arrival-first rank preserves the pre-streaming engine's tie
/// semantics: when every arrival was preloaded at init, an arrival
/// coinciding exactly with a control/sample tick (common with replay
/// files carrying coarse, tick-aligned timestamps) always carried a lower
/// insertion seq and popped first. With arrivals now scheduled
/// just-in-time their seqs are late, so the rank makes the old ordering
/// explicit instead of an accident of preloading.
#[derive(Clone, Debug)]
struct Scheduled {
    time: f64,
    rank: u8,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.rank.cmp(&self.rank))
            .then(other.seq.cmp(&self.seq))
    }
}

/// Earliest-first event queue with deterministic FIFO tie-breaking.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time: f64, event: Event) {
        debug_assert!(time.is_finite(), "non-finite event time");
        let rank = if matches!(event, Event::Arrival) { 0 } else { 1 };
        self.heap.push(Scheduled {
            time,
            rank,
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    pub fn pop(&mut self) -> Option<(f64, Event)> {
        self.heap.pop().map(|s| (s.time, s.event))
    }

    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|s| s.time)
    }

    /// Capture the full queue state for a checkpoint: every scheduled
    /// entry as `(time, rank, seq, event)` sorted in pop order, plus the
    /// next insertion sequence number. `(time, rank, seq)` is a strict
    /// total order (seqs are unique), so the sorted dump plus preserved
    /// seqs reproduces the exact pop sequence on rebuild.
    pub fn dump(&self) -> (Vec<(f64, u8, u64, Event)>, u64) {
        let mut entries: Vec<&Scheduled> = self.heap.iter().collect();
        entries.sort_by(|a, b| b.cmp(a)); // Ord is inverted for the max-heap
        (
            entries
                .into_iter()
                .map(|s| (s.time, s.rank, s.seq, s.event.clone()))
                .collect(),
            self.seq,
        )
    }

    /// Rebuild a queue from a [`EventQueue::dump`]: entries keep their
    /// original seqs (tie-break order) and future pushes continue from
    /// `next_seq`.
    pub fn rebuild(entries: Vec<(f64, u8, u64, Event)>, next_seq: u64) -> EventQueue {
        let mut q = EventQueue::new();
        for (time, rank, seq, event) in entries {
            q.heap.push(Scheduled { time, rank, seq, event });
        }
        q.seq = next_seq;
        q
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, Event::ControlTick);
        q.push(1.0, Event::Arrival);
        q.push(2.0, Event::SampleTick);
        assert_eq!(q.pop().unwrap().0, 1.0);
        assert_eq!(q.pop().unwrap().0, 2.0);
        assert_eq!(q.pop().unwrap().0, 3.0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_are_fifo() {
        let ev = |req: u64| Event::PrefillDone {
            instance: InstanceId::new(0, 0),
            req,
        };
        let mut q = EventQueue::new();
        q.push(1.0, ev(1));
        q.push(1.0, ev(2));
        q.push(1.0, ev(3));
        let order: Vec<Event> = (0..3).map(|_| q.pop().unwrap().1).collect();
        assert_eq!(order, vec![ev(1), ev(2), ev(3)]);
    }

    #[test]
    fn dump_and_rebuild_preserve_pop_order_and_ties() {
        let ev = |req: u64| Event::PrefillDone {
            instance: InstanceId::new(0, 0),
            req,
        };
        let mut q = EventQueue::new();
        q.push(2.0, ev(1));
        q.push(1.0, Event::ControlTick);
        q.push(1.0, Event::Arrival); // later push, earlier rank
        q.push(2.0, ev(2)); // FIFO tie with ev(1)
        let (entries, seq) = q.dump();
        assert_eq!(entries.len(), 4);
        // Dump is in pop order: arrival first at t=1.
        assert_eq!(entries[0].3, Event::Arrival);
        let mut rebuilt = EventQueue::rebuild(entries, seq);
        let mut order = Vec::new();
        while let Some((t, e)) = rebuilt.pop() {
            order.push((t, e));
            if let Some((qt, qe)) = q.pop() {
                assert_eq!(order.last().unwrap(), &(qt, qe));
            }
        }
        assert_eq!(order.len(), 4);
        assert_eq!(order[2].1, ev(1));
        assert_eq!(order[3].1, ev(2));
    }

    #[test]
    fn arrival_wins_exact_time_ties() {
        // A just-in-time-scheduled arrival coinciding with an earlier-
        // pushed tick must still pop first (pre-streaming semantics).
        let mut q = EventQueue::new();
        q.push(1.0, Event::ControlTick);
        q.push(1.0, Event::SampleTick);
        q.push(1.0, Event::Arrival);
        assert_eq!(q.pop().unwrap().1, Event::Arrival);
        assert_eq!(q.pop().unwrap().1, Event::ControlTick);
        assert_eq!(q.pop().unwrap().1, Event::SampleTick);
    }
}
