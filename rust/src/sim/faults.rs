//! Deterministic fault injection for the simulator.
//!
//! A [`FaultPlan`] is a serializable list of fault entries — scheduled
//! (`at`, `every`) or stochastic (`rate`, Poisson) — scoped per role or
//! per instance index. The plan is **materialized** into a concrete
//! firing list by a pure function of `(plan.seed, entries)`: no engine
//! RNG state is consumed, so armed runs are bit-reproducible and
//! checkpoint/resume can rebuild the identical firing list from the
//! config alone.
//!
//! Fault kinds (semantics live in `sim::engine`):
//!
//! * `crash` — the target vanishes instantly; in-flight prefills and
//!   decodes are lost, KV is freed, and their requests re-enter the
//!   gateway with `retries += 1` (full re-prefill cost).
//! * `preempt` — preemption with a `warning_s` drain deadline; work
//!   finishing before the deadline survives, the rest is lost as in a
//!   crash.
//! * `degrade` — a straggler window: prefill/decode step durations are
//!   multiplied by `factor` for `duration_s` seconds.
//! * `transfer` — a KVC-transfer brownout window of `duration_s`
//!   seconds: each transfer started inside the window is lost with
//!   `loss_prob` (the engine notices after a `stall_s` timeout and
//!   retries with exponential backoff, up to `max_retries` attempts
//!   before falling back to re-prefill).
//!
//! The empty plan is the default everywhere and injects nothing: the
//! engine pushes no fault events and draws no random numbers, so runs
//! with an empty plan are byte-identical to builds without this module.

use super::instance::Role;
use crate::util::json::Json;
use crate::util::rng::{splitmix64, Pcg64};

/// What a fault entry does when it fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Instant instance loss.
    Crash,
    /// Preemption with a drain warning: the target stops admitting work
    /// immediately and is force-killed `warning_s` later.
    Preempt { warning_s: f64 },
    /// Straggler window: step durations × `factor` for `duration_s`.
    Degrade { factor: f64, duration_s: f64 },
    /// KVC-transfer brownout for `duration_s`: transfers started in the
    /// window are lost with `loss_prob`; the engine times out after
    /// `stall_s`, backs off exponentially and retries up to
    /// `max_retries` times before re-prefilling.
    Transfer {
        loss_prob: f64,
        stall_s: f64,
        max_retries: u32,
        duration_s: f64,
    },
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Crash => "crash",
            FaultKind::Preempt { .. } => "preempt",
            FaultKind::Degrade { .. } => "degrade",
            FaultKind::Transfer { .. } => "transfer",
        }
    }
}

/// When a fault entry fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultSchedule {
    /// Once, at an absolute sim time.
    At { t: f64 },
    /// Periodically: `from_s, from_s + period_s, …` while `< until_s`.
    Every { period_s: f64, from_s: f64, until_s: f64 },
    /// Poisson arrivals at `rate_per_s` inside `[from_s, until_s)`,
    /// capped at `count` firings (0 = unlimited).
    Poisson {
        rate_per_s: f64,
        from_s: f64,
        until_s: f64,
        count: usize,
    },
}

/// One fault entry: a kind, an optional scope and a schedule.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    pub kind: FaultKind,
    /// Restrict targets to one role (`None` = any role).
    pub role: Option<Role>,
    /// Pin the target to the i-th matching instance (sorted by id) at
    /// fire time; `None` picks pseudo-randomly via the firing's salt.
    pub instance_index: Option<usize>,
    pub schedule: FaultSchedule,
}

/// A serializable fault-injection plan. `Default` is the empty plan.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct FaultPlan {
    /// Seed for stochastic schedules, target picks and transfer-loss
    /// draws. Independent of the workload seed.
    pub seed: u64,
    pub entries: Vec<FaultSpec>,
}

/// One concrete firing produced by [`FaultPlan::materialize`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Firing {
    /// Sim time the fault fires.
    pub t: f64,
    /// Index into `FaultPlan::entries`.
    pub entry: usize,
    /// Per-firing salt (deterministic) used for target selection.
    pub salt: u64,
}

/// Audit label for injected faults, recorded in the decision ring as
/// `Action::Fault` so `tokenscale explain` shows cause→reaction chains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultLabel {
    /// Instance crashed (unplanned loss).
    Crash,
    /// Preemption warning issued (instance began force-draining).
    Preempt,
    /// Preemption deadline hit; undrained work was lost.
    PreemptKill,
    /// Degradation window opened.
    Degrade,
    /// Degradation window closed.
    Restore,
    /// A KVC transfer exhausted its retry budget and fell back to
    /// re-prefill.
    TransferAbort,
}

impl FaultLabel {
    pub const ALL: [FaultLabel; 6] = [
        FaultLabel::Crash,
        FaultLabel::Preempt,
        FaultLabel::PreemptKill,
        FaultLabel::Degrade,
        FaultLabel::Restore,
        FaultLabel::TransferAbort,
    ];

    pub fn label(self) -> &'static str {
        match self {
            FaultLabel::Crash => "crash",
            FaultLabel::Preempt => "preempt",
            FaultLabel::PreemptKill => "preempt-kill",
            FaultLabel::Degrade => "degrade",
            FaultLabel::Restore => "restore",
            FaultLabel::TransferAbort => "transfer-abort",
        }
    }

    pub fn from_label(s: &str) -> Option<FaultLabel> {
        FaultLabel::ALL.iter().copied().find(|l| l.label() == s)
    }
}

fn role_name(r: Role) -> &'static str {
    match r {
        Role::Prefiller => "prefiller",
        Role::Decoder => "decoder",
        Role::ConvertibleDecoder => "convertible",
    }
}

fn role_from_name(s: &str) -> Option<Role> {
    match s {
        "prefiller" => Some(Role::Prefiller),
        "decoder" => Some(Role::Decoder),
        "convertible" => Some(Role::ConvertibleDecoder),
        _ => None,
    }
}

/// Deterministic per-stream seed: mixes the plan seed with a stream tag
/// so each entry (and each transfer doom-draw) gets an independent,
/// order-insensitive RNG.
pub fn mix_seed(seed: u64, a: u64, b: u64) -> u64 {
    let mut s = seed ^ a.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ b.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    splitmix64(&mut s)
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Validate parameter ranges. Returns a human-readable reason on
    /// failure (mapped to `ScenarioError::BadValue` by the scenario
    /// loader).
    pub fn validate(&self) -> Result<(), String> {
        for (i, e) in self.entries.iter().enumerate() {
            let ctx = |msg: String| format!("faults.entries[{i}]: {msg}");
            match e.kind {
                FaultKind::Crash => {}
                FaultKind::Preempt { warning_s } => {
                    if !(warning_s >= 0.0) {
                        return Err(ctx(format!("warning_s must be >= 0 (got {warning_s})")));
                    }
                }
                FaultKind::Degrade { factor, duration_s } => {
                    if !(factor >= 1.0) {
                        return Err(ctx(format!("factor must be >= 1 (got {factor})")));
                    }
                    if !(duration_s > 0.0) {
                        return Err(ctx(format!("duration_s must be > 0 (got {duration_s})")));
                    }
                }
                FaultKind::Transfer {
                    loss_prob,
                    stall_s,
                    duration_s,
                    ..
                } => {
                    if !(0.0..=1.0).contains(&loss_prob) {
                        return Err(ctx(format!("loss_prob must be in [0,1] (got {loss_prob})")));
                    }
                    if !(stall_s > 0.0) {
                        return Err(ctx(format!("stall_s must be > 0 (got {stall_s})")));
                    }
                    if !(duration_s > 0.0) {
                        return Err(ctx(format!("duration_s must be > 0 (got {duration_s})")));
                    }
                }
            }
            match e.schedule {
                FaultSchedule::At { t } => {
                    if !(t >= 0.0) {
                        return Err(ctx(format!("at must be >= 0 (got {t})")));
                    }
                }
                FaultSchedule::Every {
                    period_s,
                    from_s,
                    until_s,
                } => {
                    if !(period_s > 0.0) {
                        return Err(ctx(format!("every must be > 0 (got {period_s})")));
                    }
                    if !(from_s >= 0.0) || until_s < from_s {
                        return Err(ctx(format!(
                            "bad window from_s={from_s} until_s={until_s}"
                        )));
                    }
                }
                FaultSchedule::Poisson {
                    rate_per_s,
                    from_s,
                    until_s,
                    ..
                } => {
                    if !(rate_per_s > 0.0) {
                        return Err(ctx(format!("rate must be > 0 (got {rate_per_s})")));
                    }
                    if !(from_s >= 0.0) || until_s < from_s {
                        return Err(ctx(format!(
                            "bad window from_s={from_s} until_s={until_s}"
                        )));
                    }
                }
            }
        }
        Ok(())
    }

    /// Expand the plan into a concrete, time-sorted firing list. Pure
    /// function of the plan: each entry draws from its own seeded
    /// stream, so adding or reordering entries never perturbs another
    /// entry's firings.
    pub fn materialize(&self) -> Vec<Firing> {
        let mut out = Vec::new();
        for (idx, e) in self.entries.iter().enumerate() {
            let mut rng = Pcg64::new(mix_seed(self.seed, idx as u64, 0x5ca1ab1e));
            match e.schedule {
                FaultSchedule::At { t } => out.push(Firing {
                    t,
                    entry: idx,
                    salt: rng.next_u64(),
                }),
                FaultSchedule::Every {
                    period_s,
                    from_s,
                    until_s,
                } => {
                    let mut k = 0u32;
                    loop {
                        // Multiply instead of repeated addition so the
                        // firing times are independent of how many have
                        // fired (bit-stable under window edits).
                        let t = from_s + period_s * k as f64;
                        if t >= until_s {
                            break;
                        }
                        out.push(Firing {
                            t,
                            entry: idx,
                            salt: rng.next_u64(),
                        });
                        k += 1;
                    }
                }
                FaultSchedule::Poisson {
                    rate_per_s,
                    from_s,
                    until_s,
                    count,
                } => {
                    let mut t = from_s;
                    let mut fired = 0usize;
                    loop {
                        t += rng.exponential(rate_per_s);
                        if t >= until_s || (count > 0 && fired >= count) {
                            break;
                        }
                        out.push(Firing {
                            t,
                            entry: idx,
                            salt: rng.next_u64(),
                        });
                        fired += 1;
                    }
                }
            }
        }
        // Stable order: time, then entry index (ties across entries).
        out.sort_by(|a, b| {
            a.t.partial_cmp(&b.t)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.entry.cmp(&b.entry))
        });
        out
    }

    // ---- serialization (scenario schema + snapshots) ----

    pub fn to_json(&self) -> Json {
        let entries: Vec<Json> = self.entries.iter().map(spec_to_json).collect();
        Json::obj()
            .set("seed", self.seed as f64)
            .set("entries", Json::Arr(entries))
    }

    pub fn from_json(j: &Json) -> anyhow::Result<FaultPlan> {
        if let Json::Obj(m) = j {
            for k in m.keys() {
                if !["seed", "entries"].contains(&k.as_str()) {
                    anyhow::bail!("faults: unknown field `{k}` (typo?)");
                }
            }
        } else {
            anyhow::bail!("faults: expected an object");
        }
        let seed = j.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let mut entries = Vec::new();
        if let Some(arr) = j.get("entries").and_then(Json::as_arr) {
            for (i, ej) in arr.iter().enumerate() {
                entries.push(
                    spec_from_json(ej)
                        .map_err(|e| anyhow::anyhow!("faults.entries[{i}]: {e}"))?,
                );
            }
        }
        let plan = FaultPlan { seed, entries };
        plan.validate().map_err(|e| anyhow::anyhow!(e))?;
        Ok(plan)
    }
}

fn spec_to_json(e: &FaultSpec) -> Json {
    let mut j = Json::obj().set("kind", e.kind.name());
    if let Some(r) = e.role {
        j = j.set("role", role_name(r));
    }
    if let Some(i) = e.instance_index {
        j = j.set("instance", i as f64);
    }
    match e.kind {
        FaultKind::Crash => {}
        FaultKind::Preempt { warning_s } => {
            j = j.set("warning_s", warning_s);
        }
        FaultKind::Degrade { factor, duration_s } => {
            j = j.set("factor", factor).set("duration_s", duration_s);
        }
        FaultKind::Transfer {
            loss_prob,
            stall_s,
            max_retries,
            duration_s,
        } => {
            j = j
                .set("loss_prob", loss_prob)
                .set("stall_s", stall_s)
                .set("max_retries", max_retries as f64)
                .set("duration_s", duration_s);
        }
    }
    match e.schedule {
        FaultSchedule::At { t } => {
            j = j.set("at", t);
        }
        FaultSchedule::Every {
            period_s,
            from_s,
            until_s,
        } => {
            j = j.set("every", period_s).set("from_s", from_s);
            if until_s.is_finite() {
                j = j.set("until_s", until_s);
            }
        }
        FaultSchedule::Poisson {
            rate_per_s,
            from_s,
            until_s,
            count,
        } => {
            j = j.set("rate", rate_per_s).set("from_s", from_s);
            if until_s.is_finite() {
                j = j.set("until_s", until_s);
            }
            if count > 0 {
                j = j.set("count", count as f64);
            }
        }
    }
    j
}

fn spec_from_json(j: &Json) -> Result<FaultSpec, String> {
    let kind_str = j
        .get("kind")
        .and_then(Json::as_str)
        .ok_or("missing required field `kind`")?;

    let f = |key: &str| j.get(key).and_then(Json::as_f64);
    let req = |key: &str| f(key).ok_or(format!("`{kind_str}` needs numeric field `{key}`"));

    let kind = match kind_str {
        "crash" => FaultKind::Crash,
        "preempt" => FaultKind::Preempt {
            warning_s: req("warning_s")?,
        },
        "degrade" => FaultKind::Degrade {
            factor: req("factor")?,
            duration_s: req("duration_s")?,
        },
        "transfer" => FaultKind::Transfer {
            loss_prob: req("loss_prob")?,
            stall_s: req("stall_s")?,
            max_retries: f("max_retries").unwrap_or(3.0) as u32,
            duration_s: req("duration_s")?,
        },
        other => {
            return Err(format!(
                "unknown kind `{other}` (expected crash, preempt, degrade or transfer)"
            ))
        }
    };

    // Schedule: exactly one selector.
    let selectors = [f("at").is_some(), f("every").is_some(), f("rate").is_some()];
    if selectors.iter().filter(|x| **x).count() != 1 {
        return Err("need exactly one of `at`, `every` or `rate`".into());
    }
    let from_s = f("from_s").unwrap_or(0.0);
    let until_s = f("until_s").unwrap_or(f64::INFINITY);
    let schedule = if let Some(t) = f("at") {
        FaultSchedule::At { t }
    } else if let Some(period_s) = f("every") {
        FaultSchedule::Every {
            period_s,
            from_s,
            until_s,
        }
    } else {
        FaultSchedule::Poisson {
            rate_per_s: f("rate").unwrap(),
            from_s,
            until_s,
            count: f("count").unwrap_or(0.0) as usize,
        }
    };

    let role = match j.get("role").and_then(Json::as_str) {
        Some(s) => Some(role_from_name(s).ok_or(format!(
            "unknown role `{s}` (expected prefiller, decoder or convertible)"
        ))?),
        None => None,
    };
    let instance_index = j.get("instance").and_then(Json::as_usize);

    // Strict field check, parameterized by kind + schedule so a
    // mismatched parameter (e.g. `factor` on a crash) fails loudly.
    let mut allowed: Vec<&str> = vec!["kind", "role", "instance", "at", "every", "rate"];
    match kind_str {
        "preempt" => allowed.push("warning_s"),
        "degrade" => allowed.extend(["factor", "duration_s"]),
        "transfer" => allowed.extend(["loss_prob", "stall_s", "max_retries", "duration_s"]),
        _ => {}
    }
    if f("at").is_none() {
        allowed.extend(["from_s", "until_s"]);
        if f("rate").is_some() {
            allowed.push("count");
        }
    }
    if let Json::Obj(m) = j {
        for k in m.keys() {
            if !allowed.contains(&k.as_str()) {
                return Err(format!("unknown field `{k}` for kind `{kind_str}` (typo?)"));
            }
        }
    } else {
        return Err("expected an object".into());
    }

    Ok(FaultSpec {
        kind,
        role,
        instance_index,
        schedule,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan_with(entries: Vec<FaultSpec>) -> FaultPlan {
        FaultPlan { seed: 42, entries }
    }

    #[test]
    fn empty_plan_is_default_and_materializes_nothing() {
        let p = FaultPlan::default();
        assert!(p.is_empty());
        assert!(p.materialize().is_empty());
        assert!(p.validate().is_ok());
    }

    #[test]
    fn materialize_is_deterministic_and_sorted() {
        let p = plan_with(vec![
            FaultSpec {
                kind: FaultKind::Crash,
                role: Some(Role::Prefiller),
                instance_index: None,
                schedule: FaultSchedule::Poisson {
                    rate_per_s: 0.1,
                    from_s: 0.0,
                    until_s: 100.0,
                    count: 0,
                },
            },
            FaultSpec {
                kind: FaultKind::Crash,
                role: None,
                instance_index: Some(0),
                schedule: FaultSchedule::Every {
                    period_s: 10.0,
                    from_s: 5.0,
                    until_s: 40.0,
                },
            },
        ]);
        let a = p.materialize();
        let b = p.materialize();
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].t <= w[1].t));
        // The periodic entry fires at 5, 15, 25, 35.
        let periodic: Vec<f64> = a.iter().filter(|f| f.entry == 1).map(|f| f.t).collect();
        assert_eq!(periodic, vec![5.0, 15.0, 25.0, 35.0]);
    }

    #[test]
    fn entry_streams_are_independent() {
        // Removing the first entry must not change the second's firings.
        let e2 = FaultSpec {
            kind: FaultKind::Crash,
            role: None,
            instance_index: None,
            schedule: FaultSchedule::Poisson {
                rate_per_s: 0.2,
                from_s: 0.0,
                until_s: 50.0,
                count: 3,
            },
        };
        let solo = plan_with(vec![e2.clone()]);
        let both = plan_with(vec![
            FaultSpec {
                kind: FaultKind::Crash,
                role: None,
                instance_index: None,
                schedule: FaultSchedule::Poisson {
                    rate_per_s: 1.0,
                    from_s: 0.0,
                    until_s: 50.0,
                    count: 0,
                },
            },
            e2,
        ]);
        let solo_times: Vec<f64> = solo.materialize().iter().map(|f| f.t).collect();
        let both_times: Vec<f64> = both
            .materialize()
            .iter()
            .filter(|f| f.entry == 1)
            .map(|f| f.t)
            .collect();
        assert_eq!(solo_times, both_times);
    }

    #[test]
    fn json_round_trip() {
        let p = plan_with(vec![
            FaultSpec {
                kind: FaultKind::Preempt { warning_s: 10.0 },
                role: Some(Role::Decoder),
                instance_index: None,
                schedule: FaultSchedule::Every {
                    period_s: 30.0,
                    from_s: 20.0,
                    until_s: 200.0,
                },
            },
            FaultSpec {
                kind: FaultKind::Transfer {
                    loss_prob: 0.5,
                    stall_s: 2.0,
                    max_retries: 4,
                    duration_s: 25.0,
                },
                role: None,
                instance_index: None,
                schedule: FaultSchedule::At { t: 40.0 },
            },
            FaultSpec {
                kind: FaultKind::Degrade {
                    factor: 3.0,
                    duration_s: 15.0,
                },
                role: Some(Role::Prefiller),
                instance_index: Some(1),
                schedule: FaultSchedule::At { t: 10.0 },
            },
        ]);
        let j = p.to_json();
        let back = FaultPlan::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn strict_parsing_rejects_typos_and_mismatched_params() {
        let bad = Json::parse(r#"{"entries":[{"kind":"crash","att":5.0}]}"#).unwrap();
        assert!(FaultPlan::from_json(&bad).is_err());
        // `factor` belongs to degrade, not crash.
        let mixed =
            Json::parse(r#"{"entries":[{"kind":"crash","at":5.0,"factor":2.0}]}"#).unwrap();
        assert!(FaultPlan::from_json(&mixed).is_err());
        // Two schedule selectors.
        let twice =
            Json::parse(r#"{"entries":[{"kind":"crash","at":5.0,"every":2.0}]}"#).unwrap();
        assert!(FaultPlan::from_json(&twice).is_err());
        // Out-of-range probability.
        let oob = Json::parse(
            r#"{"entries":[{"kind":"transfer","loss_prob":1.5,"stall_s":1.0,"duration_s":5.0,"at":1.0}]}"#,
        )
        .unwrap();
        assert!(FaultPlan::from_json(&oob).is_err());
    }

    #[test]
    fn fault_labels_round_trip() {
        for l in FaultLabel::ALL {
            assert_eq!(FaultLabel::from_label(l.label()), Some(l));
        }
        assert_eq!(FaultLabel::from_label("nope"), None);
    }
}
