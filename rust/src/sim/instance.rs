//! Simulated prefiller and decoder instances: lifecycle, queues,
//! continuous batching and (for Convertible Decoders) restricted chunked
//! prefill state.

use super::event::InstanceId;
use crate::perfmodel::EngineModel;
use crate::workload::{Request, RequestId};
use std::collections::VecDeque;

/// Instance lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LifeState {
    /// Provisioned, loading weights/runtime; ready at the stored time.
    Starting,
    /// Serving.
    Running,
    /// No longer admitting work; removed once drained.
    Draining,
}

/// Role of an instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Prefiller,
    Decoder,
    /// Decoder that the router may also hand prefill work (§III-D).
    ConvertibleDecoder,
}

/// A sequence actively decoding (or waiting to join the next iteration).
#[derive(Clone, Debug)]
pub struct ActiveSeq {
    pub req: Request,
    /// Output tokens generated so far.
    pub generated: usize,
    /// Context length currently held in KV cache (input + generated).
    pub ctx: usize,
    /// Time the first output token was produced (TTFT measurement).
    pub first_token_at: Option<f64>,
    /// Predicted output bucket index (for per-type load balancing).
    pub predicted_bucket: usize,
}

/// A prefill job executing or queued on a prefiller / convertible decoder.
#[derive(Clone, Debug)]
pub struct PrefillJob {
    pub req: Request,
    /// Prompt tokens still to process (chunked prefill decrements this).
    pub remaining: usize,
    /// Arrival at this instance's queue.
    pub enqueued_at: f64,
}

/// One simulated engine instance.
#[derive(Clone, Debug)]
pub struct Instance {
    pub id: InstanceId,
    pub role: Role,
    pub life: LifeState,
    /// Time the instance becomes Running (while Starting).
    pub ready_at: f64,
    /// Time the instance was provisioned (cost accounting starts here).
    pub spawned_at: f64,
    /// Engine performance model (shared across instances of a deployment).
    pub engine: std::sync::Arc<EngineModel>,

    // ---- prefill side (prefillers + convertible decoders) ----
    pub prefill_queue: VecDeque<PrefillJob>,
    /// Currently executing prefill job (prefillers run one at a time;
    /// convertible decoders chunk it through decode iterations).
    pub active_prefill: Option<PrefillJob>,
    /// When the running prefill completes (prefillers only).
    pub prefill_done_at: f64,

    // ---- decode side (decoders + convertible decoders) ----
    /// Sequences in the continuous batch.
    pub batch: Vec<ActiveSeq>,
    /// Sequences admitted but joining at the next iteration boundary.
    pub joining: Vec<ActiveSeq>,
    /// KV tokens reserved by admitted sequences (full final footprint).
    pub reserved_tokens: f64,
    /// Monotone iteration epoch; stale DecodeIterDone events are ignored.
    pub iter_epoch: u64,
    /// Whether an iteration is currently in flight.
    pub iterating: bool,
    /// Restricted chunked-prefill budget (tokens/iteration) for
    /// convertible decoders; decode-only instances keep 0.
    pub chunk_size: usize,
    /// KV tokens reserved for burst prefill work (Eq. 6), convertibles only.
    pub convertible_reserve_tokens: f64,
}

impl Instance {
    pub fn new(
        id: InstanceId,
        role: Role,
        engine: std::sync::Arc<EngineModel>,
        now: f64,
        startup: f64,
    ) -> Instance {
        Instance {
            id,
            role,
            life: if startup <= 0.0 {
                LifeState::Running
            } else {
                LifeState::Starting
            },
            ready_at: now + startup,
            spawned_at: now,
            engine,
            prefill_queue: VecDeque::new(),
            active_prefill: None,
            prefill_done_at: f64::INFINITY,
            batch: Vec::new(),
            joining: Vec::new(),
            reserved_tokens: 0.0,
            iter_epoch: 0,
            iterating: false,
            chunk_size: 0,
            convertible_reserve_tokens: 0.0,
        }
    }

    pub fn gpus(&self) -> usize {
        self.engine.tp
    }

    pub fn is_running(&self) -> bool {
        self.life == LifeState::Running
    }

    /// Prompt tokens waiting or executing on this instance (the in-flight
    /// token count Alg. 1's waiting-time estimate divides by velocity).
    pub fn inflight_prefill_tokens(&self) -> usize {
        self.prefill_queue.iter().map(|j| j.remaining).sum::<usize>()
            + self.active_prefill.as_ref().map_or(0, |j| j.remaining)
    }

    /// KV tokens currently materialized in the batch.
    pub fn used_tokens(&self) -> f64 {
        self.batch.iter().map(|s| s.ctx as f64).sum::<f64>()
            + self.joining.iter().map(|s| s.ctx as f64).sum::<f64>()
    }

    /// Memory utilization as reserved fraction of KV capacity.
    pub fn mem_utilization(&self) -> f64 {
        let cap = self.engine.kv_capacity_tokens();
        if cap <= 0.0 {
            return 1.0;
        }
        (self.reserved_tokens / cap).min(1.0)
    }

    /// KV capacity available for new decode admissions (tokens). For
    /// convertible decoders, the Eq. 6 prefill reserve is carved out.
    pub fn admission_capacity(&self) -> f64 {
        let cap = self.engine.kv_capacity_tokens() - self.convertible_reserve_tokens;
        (cap - self.reserved_tokens).max(0.0)
    }

    /// Can this instance admit a decode sequence that will eventually hold
    /// `total_tokens` of KV?
    pub fn can_admit(&self, total_tokens: usize) -> bool {
        self.is_running() && self.admission_capacity() >= total_tokens as f64
    }

    /// Admit a sequence into the next iteration (reserves full footprint).
    pub fn admit(&mut self, seq: ActiveSeq) {
        debug_assert!(self.role != Role::Prefiller);
        self.reserved_tokens += seq.req.total_tokens() as f64;
        self.joining.push(seq);
    }

    /// Number of in-flight decode requests of a predicted bucket (for the
    /// per-type least-loaded decode LB).
    pub fn inflight_of_bucket(&self, bucket: usize) -> usize {
        self.batch
            .iter()
            .chain(self.joining.iter())
            .filter(|s| s.predicted_bucket == bucket)
            .count()
    }

    pub fn decode_load(&self) -> usize {
        self.batch.len() + self.joining.len()
    }

    /// Whether the instance has fully drained (safe to remove).
    pub fn drained(&self) -> bool {
        self.batch.is_empty()
            && self.joining.is_empty()
            && self.active_prefill.is_none()
            && self.prefill_queue.is_empty()
    }
}

/// Record of a completed (or in-progress) request's journey, kept by the
/// engine loop for TTFT/TPOT bookkeeping.
#[derive(Clone, Copy, Debug)]
pub struct RequestClock {
    pub id: RequestId,
    pub arrival: f64,
    pub prefill_started: Option<f64>,
    pub prefill_done: Option<f64>,
    pub first_token: Option<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::{catalog, EngineModel};
    use std::sync::Arc;

    fn engine() -> Arc<EngineModel> {
        Arc::new(EngineModel::new(
            catalog::model("llama-3.1-8b").unwrap(),
            catalog::gpu("a100-40g").unwrap(),
            1,
        ))
    }

    fn seq(id: u64, input: usize, output: usize) -> ActiveSeq {
        ActiveSeq {
            req: Request::new(id, 0.0, input, output),
            generated: 0,
            ctx: input,
            first_token_at: None,
            predicted_bucket: 0,
        }
    }

    #[test]
    fn starting_instance_not_running() {
        let i = Instance::new(1, Role::Decoder, engine(), 0.0, 5.0);
        assert_eq!(i.life, LifeState::Starting);
        assert!(!i.is_running());
        assert_eq!(i.ready_at, 5.0);
        let j = Instance::new(2, Role::Decoder, engine(), 0.0, 0.0);
        assert!(j.is_running());
    }

    #[test]
    fn admission_respects_capacity() {
        let mut i = Instance::new(1, Role::Decoder, engine(), 0.0, 0.0);
        let cap = i.engine.kv_capacity_tokens();
        assert!(i.can_admit(1000));
        i.admit(seq(1, 500, 500));
        assert_eq!(i.reserved_tokens, 1000.0);
        assert!(!i.can_admit(cap as usize)); // capacity reduced
    }

    #[test]
    fn convertible_reserve_shrinks_admission() {
        let mut a = Instance::new(1, Role::ConvertibleDecoder, engine(), 0.0, 0.0);
        let base = a.admission_capacity();
        a.convertible_reserve_tokens = 10_000.0;
        assert!((base - a.admission_capacity() - 10_000.0).abs() < 1e-6);
    }

    #[test]
    fn inflight_prefill_counts_queue_and_active() {
        let mut i = Instance::new(1, Role::Prefiller, engine(), 0.0, 0.0);
        i.prefill_queue.push_back(PrefillJob {
            req: Request::new(1, 0.0, 700, 10),
            remaining: 700,
            enqueued_at: 0.0,
        });
        i.active_prefill = Some(PrefillJob {
            req: Request::new(2, 0.0, 300, 10),
            remaining: 300,
            enqueued_at: 0.0,
        });
        assert_eq!(i.inflight_prefill_tokens(), 1000);
    }

    #[test]
    fn bucket_inflight_counting() {
        let mut i = Instance::new(1, Role::Decoder, engine(), 0.0, 0.0);
        let mut s1 = seq(1, 10, 10);
        s1.predicted_bucket = 3;
        let mut s2 = seq(2, 10, 10);
        s2.predicted_bucket = 3;
        let mut s3 = seq(3, 10, 10);
        s3.predicted_bucket = 5;
        i.admit(s1);
        i.batch.push(s2);
        i.admit(s3);
        assert_eq!(i.inflight_of_bucket(3), 2);
        assert_eq!(i.inflight_of_bucket(5), 1);
        assert_eq!(i.decode_load(), 3);
    }

    #[test]
    fn drained_logic() {
        let mut i = Instance::new(1, Role::Decoder, engine(), 0.0, 0.0);
        assert!(i.drained());
        i.admit(seq(1, 10, 10));
        assert!(!i.drained());
    }
}
