//! Simulated prefiller and decoder instances: lifecycle, queues,
//! continuous batching and (for Convertible Decoders) restricted chunked
//! prefill state.
//!
//! Decode iterations on a fixed batch are *coalesced*: when the batch
//! composition cannot change (no joiners, no chunked prefill, nobody
//! completing), the engine schedules one event covering many iterations
//! and this module carries the window bookkeeping. The window's effects
//! are applied lazily — either when an external touch (joiner, sample)
//! forces a catch-up, or when the window's final iteration fires — in a
//! way that is bit-identical to stepping every iteration individually
//! (context sums are exact integers in f64, and event times accumulate
//! with the same additions single-stepping would perform).

use super::event::InstanceId;
use crate::perfmodel::EngineModel;
use crate::workload::{Request, RequestId};
use std::collections::VecDeque;

/// Instance lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LifeState {
    /// Provisioned, loading weights/runtime; ready at the stored time.
    Starting,
    /// Serving.
    Running,
    /// No longer admitting work; removed once drained.
    Draining,
}

/// Role of an instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Prefiller,
    Decoder,
    /// Decoder that the router may also hand prefill work (§III-D).
    ConvertibleDecoder,
}

impl Role {
    /// Dense index used by the cluster's per-role caches.
    pub(crate) fn idx(self) -> usize {
        match self {
            Role::Prefiller => 0,
            Role::Decoder => 1,
            Role::ConvertibleDecoder => 2,
        }
    }
}

/// A sequence actively decoding (or waiting to join the next iteration).
#[derive(Clone, Debug)]
pub struct ActiveSeq {
    pub req: Request,
    /// Output tokens generated so far.
    pub generated: usize,
    /// Context length currently held in KV cache (input + generated).
    pub ctx: usize,
    /// Time the first output token was produced (TTFT measurement).
    pub first_token_at: Option<f64>,
    /// Predicted output bucket index (for per-type load balancing).
    pub predicted_bucket: usize,
}

/// A prefill job executing or queued on a prefiller / convertible decoder.
#[derive(Clone, Debug)]
pub struct PrefillJob {
    pub req: Request,
    /// Prompt tokens still to process (chunked prefill decrements this).
    /// Starts at `input_tokens − cached`: warm prefix tokens found in the
    /// instance's `sim::kvcache` at admission are never processed.
    pub remaining: usize,
    /// Warm prefix tokens skipped via the instance's prefix cache (0 for
    /// sessionless requests or disabled caches). Invariant: `remaining +
    /// processed + cached == input_tokens` throughout the job's life.
    pub cached: usize,
    /// Arrival at this instance's queue.
    pub enqueued_at: f64,
    /// Per-job chunk-budget override (deflected prefills on regular
    /// decoders): `Some(budget)` replaces the instance's configured
    /// `chunk_size` while *this* job runs, so one deflection's mode never
    /// leaks into another in-flight job. `None` everywhere else.
    pub chunk_override: Option<usize>,
}

/// One simulated engine instance.
#[derive(Clone, Debug)]
pub struct Instance {
    pub id: InstanceId,
    pub role: Role,
    pub life: LifeState,
    /// Time the instance becomes Running (while Starting).
    pub ready_at: f64,
    /// Time the instance was provisioned (cost accounting starts here).
    pub spawned_at: f64,
    /// Engine performance model (shared across instances of a deployment).
    pub engine: std::sync::Arc<EngineModel>,

    // ---- prefill side (prefillers + convertible decoders) ----
    pub prefill_queue: VecDeque<PrefillJob>,
    /// Currently executing prefill job (prefillers run one at a time;
    /// convertible decoders chunk it through decode iterations).
    pub active_prefill: Option<PrefillJob>,
    /// When the running prefill completes (prefillers only).
    pub prefill_done_at: f64,

    // ---- decode side (decoders + convertible decoders) ----
    /// Sequences in the continuous batch.
    pub batch: Vec<ActiveSeq>,
    /// Sequences admitted but joining at the next iteration boundary.
    pub joining: Vec<ActiveSeq>,
    /// KV tokens reserved by admitted sequences (full final footprint).
    pub reserved_tokens: f64,
    /// Monotone iteration epoch; stale DecodeIterDone events are ignored.
    pub iter_epoch: u64,
    /// Whether an iteration (or a coalesced window) is in flight.
    pub iterating: bool,
    /// Chunk tokens processed by the in-flight iteration (moved here from
    /// a per-event engine-side HashMap).
    pub iter_chunk: usize,
    /// Restricted chunked-prefill budget (tokens/iteration) for
    /// convertible decoders; decode-only instances keep 0.
    pub chunk_size: usize,
    /// KV tokens reserved for burst prefill work (Eq. 6), convertibles only.
    pub convertible_reserve_tokens: f64,

    // ---- prefix cache (sim::kvcache) ----
    /// Warm prefix groups held by this instance's KV cache. Disabled
    /// (capacity 0) unless the deployment opts in, in which case
    /// `Cluster::spawn` applies the configured capacity.
    pub kvcache: super::kvcache::PrefixCache,

    // ---- fault injection (sim::faults) ----
    /// Slowdown multiplier on prefill/decode step durations (straggler
    /// model). 1.0 = healthy; applied to work *started* while degraded.
    /// Multiplying by 1.0 is bit-exact, so healthy runs are untouched.
    pub perf_factor: f64,
    /// Simulated time the degradation window ends (NEG_INFINITY when
    /// healthy); the engine restores `perf_factor` to 1.0 then.
    pub degrade_until: f64,

    // ---- coalesced decode window (fixed batch fast path) ----
    /// A multi-iteration window is in flight (the scheduled
    /// DecodeIterDone covers `win_total` iterations).
    pub(crate) win_active: bool,
    /// Iterations in the window; the final one is the first that can
    /// complete a sequence.
    pub(crate) win_total: u32,
    /// Iterations already accounted (tokens counted; per-seq state applied
    /// lazily by `win_apply_to_seqs`). Capped at `win_total - 1`.
    pub(crate) win_done: u32,
    /// End time of the last accounted iteration (window start initially).
    pub(crate) win_t: f64,
    /// End time of the window's first iteration (first-token timestamp for
    /// sequences that joined at the window start).
    pub(crate) win_t1: f64,
    /// Integer sum of batch contexts at window start (exact in f64).
    pub(crate) win_sum_ctx0: u64,
}

impl Instance {
    pub fn new(
        id: InstanceId,
        role: Role,
        engine: std::sync::Arc<EngineModel>,
        now: f64,
        startup: f64,
    ) -> Instance {
        Instance {
            id,
            role,
            life: if startup <= 0.0 {
                LifeState::Running
            } else {
                LifeState::Starting
            },
            ready_at: now + startup,
            spawned_at: now,
            engine,
            prefill_queue: VecDeque::new(),
            active_prefill: None,
            prefill_done_at: f64::INFINITY,
            batch: Vec::new(),
            joining: Vec::new(),
            reserved_tokens: 0.0,
            iter_epoch: 0,
            iterating: false,
            iter_chunk: 0,
            chunk_size: 0,
            convertible_reserve_tokens: 0.0,
            kvcache: super::kvcache::PrefixCache::disabled(),
            perf_factor: 1.0,
            degrade_until: f64::NEG_INFINITY,
            win_active: false,
            win_total: 0,
            win_done: 0,
            win_t: 0.0,
            win_t1: 0.0,
            win_sum_ctx0: 0,
        }
    }

    pub fn gpus(&self) -> usize {
        self.engine.tp
    }

    pub fn is_running(&self) -> bool {
        self.life == LifeState::Running
    }

    /// Prompt tokens waiting or executing on this instance (the in-flight
    /// token count Alg. 1's waiting-time estimate divides by velocity).
    pub fn inflight_prefill_tokens(&self) -> usize {
        self.prefill_queue.iter().map(|j| j.remaining).sum::<usize>()
            + self.active_prefill.as_ref().map_or(0, |j| j.remaining)
    }

    /// Warm prefix tokens this instance could skip when prefilling `req`
    /// (read-only; no LRU touch). The signal cache-aware routers score by.
    pub fn warm_overlap(&self, req: &Request) -> usize {
        self.kvcache.overlap(req)
    }

    /// Memory utilization as reserved fraction of KV capacity.
    pub fn mem_utilization(&self) -> f64 {
        let cap = self.engine.kv_capacity_tokens();
        if cap <= 0.0 {
            return 1.0;
        }
        (self.reserved_tokens / cap).min(1.0)
    }

    /// KV capacity available for new decode admissions (tokens). For
    /// convertible decoders, the Eq. 6 prefill reserve is carved out.
    pub fn admission_capacity(&self) -> f64 {
        let cap = self.engine.kv_capacity_tokens() - self.convertible_reserve_tokens;
        (cap - self.reserved_tokens).max(0.0)
    }

    /// Can this instance admit a decode sequence that will eventually hold
    /// `total_tokens` of KV?
    pub fn can_admit(&self, total_tokens: usize) -> bool {
        self.is_running() && self.admission_capacity() >= total_tokens as f64
    }

    /// Admit a sequence into the next iteration (reserves full footprint).
    pub fn admit(&mut self, seq: ActiveSeq) {
        debug_assert!(self.role != Role::Prefiller);
        self.reserved_tokens += seq.req.total_tokens() as f64;
        self.joining.push(seq);
    }

    /// Number of in-flight decode requests of a predicted bucket (for the
    /// per-type least-loaded decode LB).
    pub fn inflight_of_bucket(&self, bucket: usize) -> usize {
        self.batch
            .iter()
            .chain(self.joining.iter())
            .filter(|s| s.predicted_bucket == bucket)
            .count()
    }

    pub fn decode_load(&self) -> usize {
        self.batch.len() + self.joining.len()
    }

    /// Whether a degradation window is currently active (straggler fault).
    pub fn is_degraded(&self) -> bool {
        self.perf_factor != 1.0
    }

    /// Whether the instance has fully drained (safe to remove).
    pub fn drained(&self) -> bool {
        self.batch.is_empty()
            && self.joining.is_empty()
            && self.active_prefill.is_none()
            && self.prefill_queue.is_empty()
    }

    // ---- coalesced-window internals (driven by the sim engine) ----

    /// Mean batch context before window iteration `i` (0-based). The sum
    /// is an exact integer in f64, so this reproduces the value
    /// single-stepping would compute by re-summing the batch.
    #[inline]
    pub(crate) fn win_avg_ctx(&self, i: u32) -> f64 {
        let n = self.batch.len() as u64;
        ((self.win_sum_ctx0 + i as u64 * n) as f64) / (n as f64)
    }

    /// Account window iterations whose end time lies strictly before `t`,
    /// capped at `win_total - 1` (the final, possibly-completing iteration
    /// is always handled by the event itself). Returns output tokens
    /// produced by the newly accounted iterations.
    pub(crate) fn win_fast_forward(&mut self, t: f64) -> f64 {
        if !self.win_active {
            return 0.0;
        }
        let n = self.batch.len();
        let mut produced = 0u64;
        while self.win_done + 1 < self.win_total {
            let avg = self.win_avg_ctx(self.win_done);
            let dur = self.engine.decode_iter_time(n, avg) * self.perf_factor;
            let end = self.win_t + dur;
            if end >= t {
                break;
            }
            self.win_t = end;
            self.win_done += 1;
            if self.win_done == 1 {
                self.win_t1 = end;
            }
            produced += n as u64;
        }
        produced as f64
    }

    /// Apply the accounted window iterations to the per-sequence state
    /// (generated / ctx bumps, first-token stamps). Idempotent per window:
    /// call exactly once, when the window ends or is truncated.
    pub(crate) fn win_apply_to_seqs(&mut self) {
        let d = self.win_done as usize;
        if d == 0 {
            return;
        }
        let t1 = self.win_t1;
        for seq in &mut self.batch {
            if seq.first_token_at.is_none() {
                seq.first_token_at = Some(t1);
            }
            seq.generated += d;
            seq.ctx += d;
        }
    }

    /// Clear window bookkeeping (after apply).
    pub(crate) fn win_clear(&mut self) {
        self.win_active = false;
        self.win_total = 0;
        self.win_done = 0;
        self.win_t = 0.0;
        self.win_t1 = 0.0;
        self.win_sum_ctx0 = 0;
    }
}

/// Record of a request's journey through the gateway, prefill stage and
/// first decode iteration, kept by the engine loop. Feeds the
/// prefill-wait / queue-delay percentiles in `SloReport`.
#[derive(Clone, Copy, Debug)]
pub struct RequestClock {
    pub id: RequestId,
    pub arrival: f64,
    /// First moment the prompt began executing (prefiller pass start, or
    /// first chunked-prefill iteration on a convertible decoder).
    pub prefill_started: Option<f64>,
    /// Prefill completion (KVC ready to ship / sequence ready to decode).
    /// First-token time lives on `ActiveSeq::first_token_at`.
    pub prefill_done: Option<f64>,
}

impl RequestClock {
    pub fn at_arrival(id: RequestId, arrival: f64) -> RequestClock {
        RequestClock {
            id,
            arrival,
            prefill_started: None,
            prefill_done: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::{catalog, EngineModel};
    use std::sync::Arc;

    fn engine() -> Arc<EngineModel> {
        Arc::new(EngineModel::new(
            catalog::model("llama-3.1-8b").unwrap(),
            catalog::gpu("a100-40g").unwrap(),
            1,
        ))
    }

    fn iid(n: u32) -> InstanceId {
        InstanceId::new(n, 0)
    }

    fn seq(id: u64, input: usize, output: usize) -> ActiveSeq {
        ActiveSeq {
            req: Request::new(id, 0.0, input, output),
            generated: 0,
            ctx: input,
            first_token_at: None,
            predicted_bucket: 0,
        }
    }

    #[test]
    fn starting_instance_not_running() {
        let i = Instance::new(iid(1), Role::Decoder, engine(), 0.0, 5.0);
        assert_eq!(i.life, LifeState::Starting);
        assert!(!i.is_running());
        assert_eq!(i.ready_at, 5.0);
        let j = Instance::new(iid(2), Role::Decoder, engine(), 0.0, 0.0);
        assert!(j.is_running());
    }

    #[test]
    fn admission_respects_capacity() {
        let mut i = Instance::new(iid(1), Role::Decoder, engine(), 0.0, 0.0);
        let cap = i.engine.kv_capacity_tokens();
        assert!(i.can_admit(1000));
        i.admit(seq(1, 500, 500));
        assert_eq!(i.reserved_tokens, 1000.0);
        assert!(!i.can_admit(cap as usize)); // capacity reduced
    }

    #[test]
    fn convertible_reserve_shrinks_admission() {
        let mut a = Instance::new(iid(1), Role::ConvertibleDecoder, engine(), 0.0, 0.0);
        let base = a.admission_capacity();
        a.convertible_reserve_tokens = 10_000.0;
        assert!((base - a.admission_capacity() - 10_000.0).abs() < 1e-6);
    }

    #[test]
    fn inflight_prefill_counts_queue_and_active() {
        let mut i = Instance::new(iid(1), Role::Prefiller, engine(), 0.0, 0.0);
        i.prefill_queue.push_back(PrefillJob {
            req: Request::new(1, 0.0, 700, 10),
            remaining: 700,
            cached: 0,
            enqueued_at: 0.0,
            chunk_override: None,
        });
        i.active_prefill = Some(PrefillJob {
            req: Request::new(2, 0.0, 300, 10),
            remaining: 300,
            cached: 0,
            enqueued_at: 0.0,
            chunk_override: None,
        });
        assert_eq!(i.inflight_prefill_tokens(), 1000);
    }

    #[test]
    fn bucket_inflight_counting() {
        let mut i = Instance::new(iid(1), Role::Decoder, engine(), 0.0, 0.0);
        let mut s1 = seq(1, 10, 10);
        s1.predicted_bucket = 3;
        let mut s2 = seq(2, 10, 10);
        s2.predicted_bucket = 3;
        let mut s3 = seq(3, 10, 10);
        s3.predicted_bucket = 5;
        i.admit(s1);
        i.batch.push(s2);
        i.admit(s3);
        assert_eq!(i.inflight_of_bucket(3), 2);
        assert_eq!(i.inflight_of_bucket(5), 1);
        assert_eq!(i.decode_load(), 3);
    }

    #[test]
    fn drained_logic() {
        let mut i = Instance::new(iid(1), Role::Decoder, engine(), 0.0, 0.0);
        assert!(i.drained());
        i.admit(seq(1, 10, 10));
        assert!(!i.drained());
    }

    #[test]
    fn window_fast_forward_matches_manual_accumulation() {
        let mut i = Instance::new(iid(1), Role::Decoder, engine(), 0.0, 0.0);
        i.batch.push(seq(1, 100, 10));
        i.batch.push(seq(2, 200, 10));
        i.win_active = true;
        i.win_total = 10;
        i.win_done = 0;
        i.win_t = 5.0;
        i.win_sum_ctx0 = 300;

        // Manually accumulate 3 iteration end times exactly as the window
        // should.
        let mut t = 5.0;
        let mut ends = Vec::new();
        for k in 0..3u64 {
            let avg = ((300 + k * 2) as f64) / 2.0;
            t += i.engine.decode_iter_time(2, avg);
            ends.push(t);
        }
        // Fast-forward strictly past the 3rd end: exactly 3 iterations.
        let produced = i.win_fast_forward(ends[2] + 1e-9);
        assert_eq!(produced, 6.0);
        assert_eq!(i.win_done, 3);
        assert_eq!(i.win_t, ends[2]);
        assert_eq!(i.win_t1, ends[0]);

        // Apply: every sequence advanced by 3, first token at t1.
        i.win_apply_to_seqs();
        assert_eq!(i.batch[0].generated, 3);
        assert_eq!(i.batch[0].ctx, 103);
        assert_eq!(i.batch[0].first_token_at, Some(ends[0]));
        assert_eq!(i.batch[1].ctx, 203);
    }

    #[test]
    fn window_fast_forward_caps_before_final_iteration() {
        let mut i = Instance::new(iid(1), Role::Decoder, engine(), 0.0, 0.0);
        i.batch.push(seq(1, 100, 4));
        i.win_active = true;
        i.win_total = 4; // final (4th) iteration completes the sequence
        i.win_t = 0.0;
        i.win_sum_ctx0 = 100;
        let produced = i.win_fast_forward(f64::INFINITY);
        // Only 3 of 4 iterations may be fast-forwarded.
        assert_eq!(i.win_done, 3);
        assert_eq!(produced, 3.0);
    }
}
