//! Per-instance KV/prefix-cache model (`sim::kvcache`).
//!
//! Each simulated instance owns a [`PrefixCache`]: a capacity-bounded
//! block pool tracking which conversational prefix groups (sessions) are
//! still warm in its KV cache. When a prefill for turn *k* of a session
//! lands on an instance that served an earlier turn, the overlapping
//! prefix is skipped — the saved tokens shrink the prefill duration in
//! the engine, the instance's in-flight token accounting (and therefore
//! the velocity/waiting-time estimates every router divides by), and are
//! surfaced in `SloReport` as hit-rate / saved-prefill-tokens.
//!
//! **Determinism contract.** The cache is a pure function of the request
//! sequence applied to it: entries are touched in event order, the
//! eviction victim is always the least-recently-touched entry with the
//! touch sequence number as a strict total order (no wall clock, no RNG,
//! no hash-iteration order — the victim scan resolves ties by session id,
//! but touch sequence numbers are unique so ties cannot occur). A
//! zero-capacity cache is free by construction: no entries are stored, no
//! counters move, every overlap is 0 — byte-identical to a build without
//! the subsystem.
//!
//! Capacity is modeled in tokens, allocated in fixed-size blocks (vLLM /
//! Dynamo style): an entry of `warm_tokens` occupies
//! `ceil(warm_tokens / block_tokens) · block_tokens`.

use crate::util::json::Json;
use crate::workload::Request;
use std::collections::HashMap;

/// Deployment-level prefix-cache configuration (per instance).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KvCacheConfig {
    /// Cache capacity in KV tokens; 0 disables the cache entirely.
    pub capacity_tokens: usize,
    /// Allocation granularity in tokens (vLLM-style paged blocks).
    pub block_tokens: usize,
}

impl KvCacheConfig {
    /// Disabled cache (capacity 0) — the default for every deployment
    /// until a scenario opts in, keeping pre-subsystem runs byte-identical.
    pub fn disabled() -> KvCacheConfig {
        KvCacheConfig {
            capacity_tokens: 0,
            block_tokens: 256,
        }
    }

    pub fn enabled(&self) -> bool {
        self.capacity_tokens > 0
    }
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        KvCacheConfig::disabled()
    }
}

/// One warm prefix group.
#[derive(Clone, Copy, Debug)]
struct Entry {
    /// Longest warm prefix of this session held by the instance, tokens.
    warm_tokens: usize,
    /// Logical LRU clock value of the last touch (unique per cache).
    touch_seq: u64,
}

/// Result of a touching cache lookup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheLookup {
    /// Warm tokens this instance can skip for the request (0 on miss).
    pub overlap: usize,
    /// Whether the lookup counted as a hit (overlap > 0).
    pub hit: bool,
}

/// Deterministic per-instance prefix cache with LRU eviction.
#[derive(Clone, Debug)]
pub struct PrefixCache {
    config: KvCacheConfig,
    entries: HashMap<u64, Entry>,
    /// Logical clock; bumped on every touch (lookup hit or insert).
    clock: u64,
    /// Block-rounded tokens currently occupied.
    occupied_tokens: usize,
    // ---- counters (monotone, serialized) ----
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl PrefixCache {
    pub fn new(config: KvCacheConfig) -> PrefixCache {
        PrefixCache {
            config,
            entries: HashMap::new(),
            clock: 0,
            occupied_tokens: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Disabled cache (the `Instance::new` default before the cluster
    /// applies its deployment config).
    pub fn disabled() -> PrefixCache {
        PrefixCache::new(KvCacheConfig::disabled())
    }

    pub fn config(&self) -> KvCacheConfig {
        self.config
    }

    pub fn enabled(&self) -> bool {
        self.config.enabled()
    }

    /// Block-rounded footprint of a `warm_tokens` entry.
    fn footprint(&self, warm_tokens: usize) -> usize {
        let b = self.config.block_tokens.max(1);
        warm_tokens.div_ceil(b) * b
    }

    /// Warm prefix groups currently held.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Block-rounded tokens currently occupied.
    pub fn occupancy_tokens(&self) -> usize {
        self.occupied_tokens
    }

    /// Occupied fraction of capacity (0.0 when disabled).
    pub fn occupancy(&self) -> f64 {
        if self.config.capacity_tokens == 0 {
            return 0.0;
        }
        self.occupied_tokens as f64 / self.config.capacity_tokens as f64
    }

    /// Read-only warm overlap for a request: how many of its re-sent
    /// prefix tokens this instance still holds. Does not touch LRU state
    /// or counters — safe for policies scoring candidates via
    /// `ClusterView`.
    pub fn overlap(&self, req: &Request) -> usize {
        let Some(s) = req.session else { return 0 };
        if !self.enabled() {
            return 0;
        }
        self.entries
            .get(&s.id)
            .map_or(0, |e| e.warm_tokens.min(s.prefix_tokens))
    }

    /// Touching lookup at prefill admission: returns the warm overlap,
    /// bumps the entry's LRU position and counts hit/miss. Sessionless
    /// requests and disabled caches return a zero-overlap lookup without
    /// moving any state (free by construction).
    pub fn lookup(&mut self, req: &Request) -> CacheLookup {
        let Some(s) = req.session else {
            return CacheLookup { overlap: 0, hit: false };
        };
        if !self.enabled() {
            return CacheLookup { overlap: 0, hit: false };
        }
        let overlap = match self.entries.get_mut(&s.id) {
            Some(e) => {
                self.clock += 1;
                e.touch_seq = self.clock;
                e.warm_tokens.min(s.prefix_tokens)
            }
            None => 0,
        };
        let hit = overlap > 0;
        if hit {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        CacheLookup { overlap, hit }
    }

    /// Record that `warm_tokens` of session `session_id` are now resident
    /// on this instance (after a prefill or a completed decode). Grows an
    /// existing entry monotonically, clamps to capacity, and evicts
    /// least-recently-touched entries until the pool fits.
    pub fn insert(&mut self, session_id: u64, warm_tokens: usize) {
        if !self.enabled() || warm_tokens == 0 {
            return;
        }
        let warm = warm_tokens.min(self.config.capacity_tokens);
        self.clock += 1;
        let clock = self.clock;
        match self.entries.get_mut(&session_id) {
            Some(e) => {
                let new_warm = e.warm_tokens.max(warm);
                self.occupied_tokens -= self.footprint(e.warm_tokens);
                self.occupied_tokens += self.footprint(new_warm);
                e.warm_tokens = new_warm;
                e.touch_seq = clock;
            }
            None => {
                self.entries.insert(
                    session_id,
                    Entry {
                        warm_tokens: warm,
                        touch_seq: clock,
                    },
                );
                self.occupied_tokens += self.footprint(warm);
            }
        }
        self.evict_to_fit(session_id);
    }

    /// Evict LRU entries until occupancy fits capacity. The freshly
    /// touched `keep` entry is never the victim (it holds the max
    /// touch_seq by construction).
    fn evict_to_fit(&mut self, keep: u64) {
        while self.occupied_tokens > self.config.capacity_tokens {
            // Victim = minimum (touch_seq, session_id). touch_seqs are
            // unique, so the id tie-break is only a belt-and-braces
            // guarantee of a total order.
            let victim = self
                .entries
                .iter()
                .filter(|(id, _)| **id != keep)
                .min_by_key(|(id, e)| (e.touch_seq, **id))
                .map(|(id, _)| *id);
            let Some(v) = victim else { break };
            if let Some(e) = self.entries.remove(&v) {
                self.occupied_tokens -= self.footprint(e.warm_tokens);
                self.evictions += 1;
            }
        }
    }

    /// Drop every entry (conversion keeps the cache; crash/removal drops
    /// the whole instance, so this is only used by tests and future
    /// policies). Counters are preserved.
    pub fn clear(&mut self) {
        self.entries.clear();
        self.occupied_tokens = 0;
    }

    /// Bit-exact serialization for `sim::snapshot`; entries sorted by
    /// session id so the text form is canonical.
    pub fn to_json(&self) -> Json {
        let mut ids: Vec<u64> = self.entries.keys().copied().collect();
        ids.sort_unstable();
        Json::obj()
            .set("capacity_tokens", self.config.capacity_tokens)
            .set("block_tokens", self.config.block_tokens)
            .set("clock", Json::u64_hex(self.clock))
            .set("occupied_tokens", self.occupied_tokens)
            .set("hits", Json::u64_hex(self.hits))
            .set("misses", Json::u64_hex(self.misses))
            .set("evictions", Json::u64_hex(self.evictions))
            .set(
                "entries",
                Json::Arr(
                    ids.iter()
                        .map(|id| {
                            let e = &self.entries[id];
                            Json::obj()
                                .set("session", Json::u64_hex(*id))
                                .set("warm", e.warm_tokens)
                                .set("touch", Json::u64_hex(e.touch_seq))
                        })
                        .collect(),
                ),
            )
    }

    /// Rebuild from [`PrefixCache::to_json`] output.
    pub fn from_json(j: &Json) -> anyhow::Result<PrefixCache> {
        let what = "kvcache snapshot";
        let get = |key: &str| -> anyhow::Result<&Json> {
            j.get(key).ok_or_else(|| anyhow::anyhow!("{what}: missing `{key}`"))
        };
        let usz = |key: &str| -> anyhow::Result<usize> {
            get(key)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("{what}: bad `{key}`"))
        };
        let u64f = |key: &str| -> anyhow::Result<u64> {
            get(key)?
                .as_u64_hex()
                .ok_or_else(|| anyhow::anyhow!("{what}: bad `{key}`"))
        };
        let mut cache = PrefixCache::new(KvCacheConfig {
            capacity_tokens: usz("capacity_tokens")?,
            block_tokens: usz("block_tokens")?,
        });
        cache.clock = u64f("clock")?;
        cache.occupied_tokens = usz("occupied_tokens")?;
        cache.hits = u64f("hits")?;
        cache.misses = u64f("misses")?;
        cache.evictions = u64f("evictions")?;
        let arr = get("entries")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("{what}: `entries` is not an array"))?;
        for e in arr {
            let id = e
                .get("session")
                .and_then(Json::as_u64_hex)
                .ok_or_else(|| anyhow::anyhow!("{what}: bad entry session"))?;
            let warm = e
                .get("warm")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("{what}: bad entry warm"))?;
            let touch = e
                .get("touch")
                .and_then(Json::as_u64_hex)
                .ok_or_else(|| anyhow::anyhow!("{what}: bad entry touch"))?;
            cache.entries.insert(
                id,
                Entry {
                    warm_tokens: warm,
                    touch_seq: touch,
                },
            );
        }
        Ok(cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Request;

    fn cfg(cap: usize, block: usize) -> KvCacheConfig {
        KvCacheConfig {
            capacity_tokens: cap,
            block_tokens: block,
        }
    }

    fn req(id: u64, input: usize, session: u64, prefix: usize) -> Request {
        Request::new(id, 0.0, input, 10).with_session(session, prefix)
    }

    #[test]
    fn disabled_cache_is_inert() {
        let mut c = PrefixCache::disabled();
        let r = req(1, 1000, 7, 500);
        assert_eq!(c.overlap(&r), 0);
        assert_eq!(c.lookup(&r), CacheLookup { overlap: 0, hit: false });
        c.insert(7, 1000);
        assert!(c.is_empty());
        assert_eq!(c.hits + c.misses + c.evictions, 0);
        assert_eq!(c.occupancy(), 0.0);
    }

    #[test]
    fn sessionless_requests_never_touch_state() {
        let mut c = PrefixCache::new(cfg(10_000, 256));
        let r = Request::new(1, 0.0, 500, 10);
        assert_eq!(c.lookup(&r), CacheLookup { overlap: 0, hit: false });
        assert_eq!(c.hits + c.misses, 0, "sessionless lookups are free");
    }

    #[test]
    fn overlap_is_min_of_warm_and_prefix() {
        let mut c = PrefixCache::new(cfg(100_000, 1));
        c.insert(7, 600);
        // Prefix longer than warm: only the warm part overlaps.
        assert_eq!(c.overlap(&req(1, 2000, 7, 900)), 600);
        // Prefix shorter than warm: the whole prefix overlaps.
        assert_eq!(c.overlap(&req(2, 2000, 7, 400)), 400);
        // Different session: nothing.
        assert_eq!(c.overlap(&req(3, 2000, 8, 400)), 0);
    }

    #[test]
    fn lookup_counts_hits_and_misses() {
        let mut c = PrefixCache::new(cfg(100_000, 1));
        c.insert(7, 600);
        assert!(c.lookup(&req(1, 2000, 7, 500)).hit);
        assert!(!c.lookup(&req(2, 2000, 8, 500)).hit);
        // First turn (prefix 0) on a warm session is a miss: nothing to save.
        assert!(!c.lookup(&req(3, 2000, 7, 0)).hit);
        assert_eq!((c.hits, c.misses), (1, 2));
    }

    #[test]
    fn insert_grows_monotonically_and_rounds_to_blocks() {
        let mut c = PrefixCache::new(cfg(10_000, 256));
        c.insert(1, 100);
        assert_eq!(c.occupancy_tokens(), 256);
        c.insert(1, 300); // grows
        assert_eq!(c.occupancy_tokens(), 512);
        c.insert(1, 200); // never shrinks
        assert_eq!(c.occupancy_tokens(), 512);
        assert_eq!(c.overlap(&req(1, 1000, 1, 1000)), 300);
    }

    #[test]
    fn eviction_is_lru_and_deterministic() {
        let mut c = PrefixCache::new(cfg(1024, 256));
        c.insert(1, 256);
        c.insert(2, 256);
        c.insert(3, 256);
        c.insert(4, 256);
        assert_eq!(c.len(), 4);
        // Touch 1 so 2 becomes the LRU victim.
        assert!(c.lookup(&req(9, 1000, 1, 200)).hit);
        c.insert(5, 256);
        assert_eq!(c.len(), 4);
        assert_eq!(c.evictions, 1);
        assert_eq!(c.overlap(&req(10, 1000, 2, 200)), 0, "2 evicted");
        assert_eq!(c.overlap(&req(11, 1000, 1, 200)), 200, "1 survived");
        // Replaying the same ops gives the same victims.
        let replay = || {
            let mut c = PrefixCache::new(cfg(1024, 256));
            for s in 1..=4 {
                c.insert(s, 256);
            }
            c.lookup(&req(9, 1000, 1, 200));
            c.insert(5, 256);
            let mut ids: Vec<u64> = (1..=5)
                .filter(|s| c.overlap(&req(0, 1000, *s, 1)) > 0)
                .collect();
            ids.sort_unstable();
            ids
        };
        assert_eq!(replay(), replay());
    }

    #[test]
    fn oversized_insert_clamps_to_capacity() {
        let mut c = PrefixCache::new(cfg(1000, 256));
        c.insert(1, 50_000);
        assert_eq!(c.len(), 1);
        assert_eq!(c.overlap(&req(1, 60_000, 1, 60_000)), 1000);
        // Block rounding may exceed capacity by a partial block; the entry
        // itself is never evicted.
        c.insert(2, 256);
        assert!(c.len() >= 1);
    }

    #[test]
    fn snapshot_round_trips_bit_exactly_through_text() {
        let mut c = PrefixCache::new(cfg(4096, 128));
        c.insert(3, 500);
        c.insert(u64::MAX - 1, 900);
        c.lookup(&req(1, 1000, 3, 400));
        c.lookup(&req(2, 1000, 99, 400));
        c.insert(42, 4000); // forces an eviction
        let text = c.to_json().pretty();
        let back = PrefixCache::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().pretty(), text);
        assert_eq!(back.hits, c.hits);
        assert_eq!(back.misses, c.misses);
        assert_eq!(back.evictions, c.evictions);
        assert_eq!(back.occupancy_tokens(), c.occupancy_tokens());
        // LRU clock resumes: the same next operation evicts the same victim.
        let mut a = c.clone();
        let mut b = back;
        a.insert(77, 4000);
        b.insert(77, 4000);
        assert_eq!(a.to_json().pretty(), b.to_json().pretty());
    }
}
