//! **Frozen v1 control-plane API** — equivalence oracle for the v2
//! redesign, scheduled for deletion one PR after the migration settles.
//!
//! This module preserves, verbatim, the pre-redesign `Coordinator` trait
//! and the engine loop that drove it, so the `control_plane_equivalence`
//! integration test can prove the action-based v2 engine reproduces the
//! old mechanics bit for bit: the same policies (which now natively
//! implement [`ControlPlane`](super::policy::ControlPlane)) are run
//! through [`V1Bridge`] + [`LegacySimEngine`] and through the v2
//! `SimEngine`, and their `SloReport`s must match on every byte.
//!
//! Nothing outside `rust/tests/` should use this module.

#![doc(hidden)]

use super::cluster::{Cluster, ClusterConfig};
use super::engine::{SimConfig, SimResult, SimSeries};
use super::event::{Event, EventQueue, InstanceId};
use super::instance::{ActiveSeq, LifeState, PrefillJob, RequestClock, Role};
use super::policy::{Action, ControlPlane, Signal};
use super::view::ClusterView;
use crate::metrics::MetricsRecorder;
use crate::trace::{ArrivalSource, Trace, TraceSliceSource};
use crate::workload::{Completion, Request, RequestId};
use std::collections::{HashMap, VecDeque};

/// Where a request's prefill should execute (v1 routing answer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    Prefiller(InstanceId),
    Convertible(InstanceId),
    Queue,
}

/// Desired instance counts from a v1 autoscaler evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScaleTargets {
    pub prefillers: usize,
    pub decoders: usize,
}

/// The pre-redesign control-plane trait: two fixed questions plus
/// notifications, answered against a raw `&Cluster`.
pub trait Coordinator {
    fn name(&self) -> &str;
    fn observe_arrival(&mut self, now: f64, req: &Request);
    fn route_prefill(&mut self, now: f64, req: &Request, cluster: &Cluster) -> Route;
    fn route_decode(&mut self, now: f64, req: &Request, cluster: &Cluster) -> Option<InstanceId>;
    fn scale(&mut self, now: f64, cluster: &Cluster) -> ScaleTargets;
    fn predict_bucket(&mut self, req: &Request) -> usize;
    fn live_scaling(&self) -> bool {
        false
    }
    fn observe_completion(&mut self, _now: f64, _completion: &Completion) {}
}

/// Adapter driving a native v2 [`ControlPlane`] through the v1
/// [`Coordinator`] surface, reproducing the old engine's exact call
/// pattern (observe-then-route, separate bucket query) without any extra
/// policy-side work.
pub struct V1Bridge<'p> {
    plane: &'p mut dyn ControlPlane,
    actions: Vec<Action>,
    /// Arrival seen by `observe_arrival`, consumed by the paired
    /// `route_prefill` call (the v1 engine always calls them
    /// back-to-back).
    staged_arrival: Option<RequestId>,
    /// Bucket carried by the last `DispatchDecode` answer, consumed by the
    /// engine's follow-up `predict_bucket` call.
    staged_bucket: Option<usize>,
    /// Empty cluster standing in for the view on v1 callbacks that carry
    /// no cluster argument (`observe_completion`).
    detached: Cluster,
}

impl<'p> V1Bridge<'p> {
    pub fn new(plane: &'p mut dyn ControlPlane, cfg: ClusterConfig) -> V1Bridge<'p> {
        V1Bridge {
            plane,
            actions: Vec::new(),
            staged_arrival: None,
            staged_bucket: None,
            detached: Cluster::new(cfg),
        }
    }

    fn dispatch(&mut self, now: f64, signal: Signal<'_>, cluster: &Cluster) {
        self.actions.clear();
        let plane = &mut *self.plane;
        let view = ClusterView::new(cluster);
        plane.on_signal(now, signal, &view, &mut self.actions);
    }
}

impl Coordinator for V1Bridge<'_> {
    fn name(&self) -> &str {
        self.plane.name()
    }

    fn observe_arrival(&mut self, _now: f64, req: &Request) {
        self.staged_arrival = Some(req.id);
    }

    fn route_prefill(&mut self, now: f64, req: &Request, cluster: &Cluster) -> Route {
        let fresh = self.staged_arrival.take() == Some(req.id);
        if fresh {
            self.dispatch(now, Signal::Arrival(req), cluster);
        } else {
            self.dispatch(now, Signal::RetryPrefill(req), cluster);
        }
        for a in &self.actions {
            if let Action::RoutePrefill { req: rid, target } = a {
                if *rid == req.id {
                    return match cluster.get(*target).map(|i| i.role) {
                        Some(Role::ConvertibleDecoder) => Route::Convertible(*target),
                        _ => Route::Prefiller(*target),
                    };
                }
            }
        }
        // DeflectPrefill and friends are inexpressible in v1: queue.
        Route::Queue
    }

    fn route_decode(&mut self, now: f64, req: &Request, cluster: &Cluster) -> Option<InstanceId> {
        self.dispatch(now, Signal::PrefillDone(req), cluster);
        for a in &self.actions {
            if let Action::DispatchDecode { req: rid, decoder, bucket } = a {
                if *rid == req.id {
                    self.staged_bucket = Some(*bucket);
                    return Some(*decoder);
                }
            }
        }
        None
    }

    fn scale(&mut self, now: f64, cluster: &Cluster) -> ScaleTargets {
        self.dispatch(now, Signal::Tick, cluster);
        let mut t = ScaleTargets {
            prefillers: cluster.active_count(Role::Prefiller),
            decoders: cluster.active_count(Role::Decoder),
        };
        for a in &self.actions {
            if let Action::SetFleet { role, target } = a {
                match role {
                    Role::Prefiller => t.prefillers = *target,
                    Role::Decoder => t.decoders = *target,
                    Role::ConvertibleDecoder => {}
                }
            }
        }
        t
    }

    fn predict_bucket(&mut self, _req: &Request) -> usize {
        // Called by the v1 engine after a successful `route_decode` (uses
        // the staged bucket) and on convertible prefill admission (value
        // discarded there; v2-native policies burn the matching RNG draw
        // themselves, so no forwarding happens here).
        self.staged_bucket.take().unwrap_or(0)
    }

    fn live_scaling(&self) -> bool {
        self.plane.live_scaling()
    }

    fn observe_completion(&mut self, now: f64, completion: &Completion) {
        self.actions.clear();
        let plane = &mut *self.plane;
        let view = ClusterView::new(&self.detached);
        plane.on_signal(now, Signal::Completion(completion), &view, &mut self.actions);
    }
}

/// In-flight KVC transfer bookkeeping (v1 copy).
struct Transfer {
    bytes_per_s: f64,
}

/// Frozen copy of the pre-redesign simulation engine. Mechanics are the
/// same code the v2 engine evolved from; only the control-plane dispatch
/// differs (direct trait calls instead of signal/action exchange).
pub struct LegacySimEngine<'a, C: Coordinator> {
    cfg: SimConfig,
    coordinator: &'a mut C,
    cluster: Cluster,
    events: EventQueue,
    arrivals: &'a mut dyn ArrivalSource,
    duration_s: f64,
    next_arrival: Option<Request>,
    now: f64,
    pending: VecDeque<Request>,
    awaiting_decode: VecDeque<Request>,
    transfers: HashMap<RequestId, Transfer>,
    net_bytes_per_s: f64,
    in_transfer: HashMap<RequestId, (Request, usize)>,
    clocks: HashMap<RequestId, RequestClock>,
    metrics: MetricsRecorder,
    series: SimSeries,
    ttft_points: Vec<(f64, f64)>,
    tokens_since_sample: f64,
    last_sample_t: f64,
    scale_ups: usize,
    scale_downs: usize,
    events_processed: u64,
    completions_buf: Vec<Completion>,
    batch_scratch: Vec<ActiveSeq>,
}

impl<'a, C: Coordinator> LegacySimEngine<'a, C> {
    pub fn new(
        cfg: SimConfig,
        cluster_cfg: ClusterConfig,
        coordinator: &'a mut C,
        arrivals: &'a mut dyn ArrivalSource,
    ) -> Self {
        let duration_s = arrivals.duration_s();
        LegacySimEngine {
            cfg,
            coordinator,
            cluster: Cluster::new(cluster_cfg),
            events: EventQueue::new(),
            arrivals,
            duration_s,
            next_arrival: None,
            now: 0.0,
            pending: VecDeque::new(),
            awaiting_decode: VecDeque::new(),
            transfers: HashMap::new(),
            net_bytes_per_s: 0.0,
            in_transfer: HashMap::new(),
            clocks: HashMap::new(),
            metrics: MetricsRecorder::new(),
            series: SimSeries::default(),
            ttft_points: Vec::new(),
            tokens_since_sample: 0.0,
            last_sample_t: 0.0,
            scale_ups: 0,
            scale_downs: 0,
            events_processed: 0,
            completions_buf: Vec::new(),
            batch_scratch: Vec::new(),
        }
    }

    pub fn run(mut self) -> SimResult {
        for _ in 0..self.cfg.initial_prefillers {
            self.cluster.spawn(Role::Prefiller, 0.0, Some(0.0));
        }
        for _ in 0..self.cfg.initial_decoders {
            self.cluster.spawn(Role::Decoder, 0.0, Some(0.0));
        }
        for _ in 0..self.cfg.initial_convertibles {
            self.cluster.spawn(Role::ConvertibleDecoder, 0.0, Some(0.0));
        }
        self.next_arrival = self.arrivals.next_request();
        if let Some(r) = &self.next_arrival {
            self.events.push(r.arrival.max(0.0), Event::Arrival);
        }
        self.events.push(0.0, Event::ControlTick);
        self.events.push(0.0, Event::SampleTick);

        let horizon = self.duration_s + self.cfg.drain_s;
        while let Some((t, ev)) = self.events.pop() {
            if t > horizon {
                break;
            }
            self.now = t;
            self.events_processed += 1;
            self.handle(ev);
            if self.now > self.duration_s
                && self.next_arrival.is_none()
                && self.pending.is_empty()
                && self.awaiting_decode.is_empty()
                && self.all_idle()
            {
                break;
            }
        }
        let end = self.now.max(self.duration_s);
        self.cluster.accrue_cost(end);
        self.metrics.gpu_seconds = self.cluster.gpu_seconds;
        self.metrics.horizon_s = end;
        self.metrics.workload_s = self.duration_s;
        SimResult {
            metrics: self.metrics,
            series: self.series,
            prefiller_series: self.cluster.prefiller_series.clone(),
            decoder_series: self.cluster.decoder_series.clone(),
            ttft_points: self.ttft_points,
            horizon_s: end,
            scale_ups: self.scale_ups,
            scale_downs: self.scale_downs,
            events_processed: self.events_processed,
            decisions: None,
        }
    }

    fn all_idle(&self) -> bool {
        self.transfers.is_empty() && self.cluster.iter().all(|i| i.drained())
    }

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::Arrival => {
                let Some(req) = self.next_arrival.take() else {
                    return;
                };
                self.next_arrival = self.arrivals.next_request();
                if let Some(n) = &self.next_arrival {
                    self.events.push(n.arrival.max(self.now), Event::Arrival);
                }
                self.metrics.note_arrival(&req);
                self.clocks
                    .insert(req.id, RequestClock::at_arrival(req.id, req.arrival));
                self.coordinator.observe_arrival(self.now, &req);
                self.dispatch_prefill(req);
            }
            Event::ControlTick => {
                self.catch_up_windows();
                self.control_tick();
                self.events
                    .push(self.now + self.cfg.control_interval_s, Event::ControlTick);
            }
            Event::SampleTick => {
                self.catch_up_windows();
                self.sample();
                self.events
                    .push(self.now + self.cfg.sample_interval_s, Event::SampleTick);
            }
            Event::InstanceReady { instance } => {
                if let Some(inst) = self.cluster.get_mut(instance) {
                    if inst.life == LifeState::Starting {
                        inst.life = LifeState::Running;
                    }
                }
                self.reoffer_pending();
                self.maybe_start_prefill(instance);
            }
            Event::PrefillDone { instance, req } => self.on_prefill_done(instance, req),
            Event::TransferDone { instance, req } => self.on_transfer_done(instance, req),
            Event::DecodeIterDone { instance, epoch } => self.on_iter_done(instance, epoch),
        }
    }

    fn dispatch_prefill(&mut self, req: Request) {
        match self.coordinator.route_prefill(self.now, &req, &self.cluster) {
            Route::Prefiller(id) => {
                let job = PrefillJob {
                    remaining: req.input_tokens,
                    req,
                    enqueued_at: self.now,
                    chunk_override: None,
                };
                if let Some(inst) = self.cluster.get_mut(id) {
                    inst.prefill_queue.push_back(job);
                } else {
                    self.pending.push_back(job.req);
                    return;
                }
                self.maybe_start_prefill(id);
            }
            Route::Convertible(id) => self.admit_convertible_prefill(id, req),
            Route::Queue => self.pending.push_back(req),
        }
    }

    fn admit_convertible_prefill(&mut self, id: InstanceId, req: Request) {
        let bucket = self.coordinator.predict_bucket(&req);
        let job = PrefillJob {
            remaining: req.input_tokens,
            req,
            enqueued_at: self.now,
            chunk_override: None,
        };
        self.interrupt_window(id);
        let Some(inst) = self.cluster.get_mut(id) else {
            self.pending.push_back(job.req);
            return;
        };
        inst.reserved_tokens += job.req.total_tokens() as f64;
        inst.prefill_queue.push_back(job);
        let _ = bucket;
        self.ensure_iterating(id);
    }

    fn maybe_start_prefill(&mut self, id: InstanceId) {
        let Some(inst) = self.cluster.get_mut(id) else {
            return;
        };
        if inst.role != Role::Prefiller
            || inst.active_prefill.is_some()
            || inst.life == LifeState::Starting
        {
            return;
        }
        let Some(job) = inst.prefill_queue.pop_front() else {
            return;
        };
        let dur = inst.engine.prefill_time(job.req.input_tokens);
        let req_id = job.req.id;
        inst.active_prefill = Some(job);
        inst.prefill_done_at = self.now + dur;
        if let Some(ck) = self.clocks.get_mut(&req_id) {
            if ck.prefill_started.is_none() {
                ck.prefill_started = Some(self.now);
            }
        }
        self.events.push(
            self.now + dur,
            Event::PrefillDone {
                instance: id,
                req: req_id,
            },
        );
    }

    fn on_prefill_done(&mut self, instance: InstanceId, req_id: RequestId) {
        let Some(inst) = self.cluster.get_mut(instance) else {
            return;
        };
        let Some(job) = inst.active_prefill.take() else {
            return;
        };
        debug_assert_eq!(job.req.id, req_id);
        inst.prefill_done_at = f64::INFINITY;
        if let Some(ck) = self.clocks.get_mut(&req_id) {
            ck.prefill_done = Some(self.now);
        }
        self.maybe_start_prefill(instance);
        self.try_send_to_decoder(job.req);
    }

    fn try_send_to_decoder(&mut self, req: Request) {
        let max_capacity = self.cluster.config.decode_engine.kv_capacity_tokens();
        if req.total_tokens() as f64 > max_capacity {
            self.metrics.dropped += 1;
            if self.metrics.dropped == 1 {
                eprintln!(
                    "[sim] request {} needs {} KV tokens > decoder capacity {:.0}; rejecting \
                     (further oversized requests counted in metrics.dropped)",
                    req.id,
                    req.total_tokens(),
                    max_capacity
                );
            }
            self.clocks.remove(&req.id);
            return;
        }
        match self.coordinator.route_decode(self.now, &req, &self.cluster) {
            Some(decoder) => {
                let bucket = self.coordinator.predict_bucket(&req);
                let Some(inst) = self.cluster.get_mut(decoder) else {
                    self.awaiting_decode.push_back(req);
                    return;
                };
                inst.reserved_tokens += req.total_tokens() as f64;
                let bytes = inst.engine.kvc_bytes(req.input_tokens);
                let dur = self.cfg.link.transfer_time(bytes);
                let bytes_per_s = bytes / dur.max(1e-9);
                self.transfers.insert(req.id, Transfer { bytes_per_s });
                self.net_bytes_per_s += bytes_per_s;
                self.events.push(
                    self.now + dur,
                    Event::TransferDone {
                        instance: decoder,
                        req: req.id,
                    },
                );
                self.in_transfer.insert(req.id, (req, bucket));
            }
            None => self.awaiting_decode.push_back(req),
        }
    }

    fn on_transfer_done(&mut self, instance: InstanceId, req_id: RequestId) {
        if let Some(tr) = self.transfers.remove(&req_id) {
            self.net_bytes_per_s = (self.net_bytes_per_s - tr.bytes_per_s).max(0.0);
        }
        let Some((req, bucket)) = self.in_transfer.remove(&req_id) else {
            return;
        };
        self.interrupt_window(instance);
        let Some(inst) = self.cluster.get_mut(instance) else {
            return;
        };
        inst.joining.push(ActiveSeq {
            ctx: req.input_tokens,
            generated: 0,
            first_token_at: None,
            predicted_bucket: bucket,
            req,
        });
        self.ensure_iterating(instance);
    }

    fn catch_up_windows(&mut self) {
        let now = self.now;
        let mut produced = 0.0;
        for role in [Role::Decoder, Role::ConvertibleDecoder] {
            self.cluster.for_each_role_mut(role, |inst| {
                if inst.win_active {
                    produced += inst.win_fast_forward(now);
                }
            });
        }
        self.tokens_since_sample += produced;
    }

    fn interrupt_window(&mut self, id: InstanceId) {
        let now = self.now;
        let mut produced = 0.0;
        let mut reschedule = None;
        if let Some(inst) = self.cluster.get_mut(id) {
            if inst.win_active {
                produced = inst.win_fast_forward(now);
                let n = inst.batch.len();
                let avg = inst.win_avg_ctx(inst.win_done);
                let dur = inst.engine.decode_iter_time(n, avg);
                let end = inst.win_t + dur;
                inst.win_apply_to_seqs();
                inst.win_clear();
                inst.iter_epoch += 1;
                reschedule = Some((end, inst.iter_epoch));
            }
        }
        if let Some((end, epoch)) = reschedule {
            self.events
                .push(end, Event::DecodeIterDone { instance: id, epoch });
        }
        self.tokens_since_sample += produced;
    }

    fn ensure_iterating(&mut self, id: InstanceId) {
        let force_single = self.cfg.force_single_step;
        let now = self.now;
        let Some(inst) = self.cluster.get_mut(id) else {
            return;
        };
        if !inst.is_running() && inst.life != LifeState::Draining {
            return;
        }
        if inst.iterating {
            return;
        }
        let joiners = std::mem::take(&mut inst.joining);
        inst.batch.extend(joiners);
        let max_batch = 256;
        if inst.batch.len() > max_batch {
            let overflow = inst.batch.split_off(max_batch);
            inst.joining = overflow;
        }

        let mut chunk_tokens = 0usize;
        let mut chunk_first_start: Option<RequestId> = None;
        if inst.role == Role::ConvertibleDecoder {
            if inst.active_prefill.is_none() {
                inst.active_prefill = inst.prefill_queue.pop_front();
            }
            if let Some(job) = &inst.active_prefill {
                let budget = inst.chunk_size.saturating_sub(inst.batch.len());
                chunk_tokens = budget.min(job.remaining);
                if chunk_tokens > 0 && job.remaining == job.req.input_tokens {
                    chunk_first_start = Some(job.req.id);
                }
            }
        }

        if inst.batch.is_empty() && chunk_tokens == 0 {
            return;
        }

        let n = inst.batch.len();
        let sum_ctx: u64 = inst.batch.iter().map(|s| s.ctx as u64).sum();
        let avg_ctx = if n == 0 {
            0.0
        } else {
            (sum_ctx as f64) / (n as f64)
        };
        let dur = if chunk_tokens > 0 {
            inst.engine.chunked_iter_time(chunk_tokens, n, avg_ctx)
        } else {
            inst.engine.decode_iter_time(n, avg_ctx)
        };
        inst.iterating = true;
        inst.iter_epoch += 1;
        inst.iter_chunk = chunk_tokens;
        let epoch = inst.iter_epoch;

        let mut end = now + dur;
        let coalescible = !force_single
            && chunk_tokens == 0
            && n > 0
            && inst.joining.is_empty()
            && inst.active_prefill.is_none()
            && inst.prefill_queue.is_empty();
        if coalescible {
            let min_remaining = inst
                .batch
                .iter()
                .map(|s| s.req.output_tokens.saturating_sub(s.generated).max(1))
                .min()
                .unwrap_or(1);
            if min_remaining > 1 {
                let total = min_remaining as u32;
                let mut t = end;
                for i in 1..total {
                    let avg = ((sum_ctx + i as u64 * n as u64) as f64) / (n as f64);
                    t += inst.engine.decode_iter_time(n, avg);
                }
                inst.win_active = true;
                inst.win_total = total;
                inst.win_done = 0;
                inst.win_t = now;
                inst.win_t1 = 0.0;
                inst.win_sum_ctx0 = sum_ctx;
                end = t;
            }
        }
        self.events
            .push(end, Event::DecodeIterDone { instance: id, epoch });
        if let Some(rid) = chunk_first_start {
            if let Some(ck) = self.clocks.get_mut(&rid) {
                if ck.prefill_started.is_none() {
                    ck.prefill_started = Some(now);
                }
            }
        }
    }

    fn on_iter_done(&mut self, id: InstanceId, epoch: u64) {
        self.completions_buf.clear();
        let mut freed = false;
        let mut produced = 0.0;
        let now = self.now;
        {
            let Some(inst) = self.cluster.get_mut(id) else {
                return;
            };
            if epoch != inst.iter_epoch {
                return;
            }
            inst.iterating = false;
            let chunk = inst.iter_chunk;
            inst.iter_chunk = 0;

            if inst.win_active {
                produced += inst.win_fast_forward(f64::INFINITY);
                inst.win_apply_to_seqs();
                inst.win_clear();
            }

            if chunk > 0 {
                if let Some(job) = &mut inst.active_prefill {
                    job.remaining = job.remaining.saturating_sub(chunk);
                    if job.remaining == 0 {
                        let job = inst.active_prefill.take().unwrap();
                        let bucket = crate::workload::BucketScheme::default()
                            .classify(job.req.input_tokens, job.req.output_tokens)
                            .index();
                        if let Some(ck) = self.clocks.get_mut(&job.req.id) {
                            ck.prefill_done = Some(now);
                        }
                        inst.joining.push(ActiveSeq {
                            ctx: job.req.input_tokens,
                            generated: 0,
                            first_token_at: None,
                            predicted_bucket: bucket,
                            req: job.req,
                        });
                    }
                }
            }

            produced += inst.batch.len() as f64;
            let mut scratch = std::mem::take(&mut self.batch_scratch);
            scratch.clear();
            for mut seq in inst.batch.drain(..) {
                seq.generated += 1;
                seq.ctx += 1;
                if seq.first_token_at.is_none() {
                    seq.first_token_at = Some(now);
                }
                if seq.generated >= seq.req.output_tokens {
                    inst.reserved_tokens =
                        (inst.reserved_tokens - seq.req.total_tokens() as f64).max(0.0);
                    freed = true;
                    let first = seq.first_token_at.unwrap();
                    let ttft = first - seq.req.arrival;
                    let tpot = if seq.req.output_tokens > 1 {
                        (now - first) / (seq.req.output_tokens - 1) as f64
                    } else {
                        0.0
                    };
                    self.completions_buf.push(Completion {
                        id: seq.req.id,
                        arrival: seq.req.arrival,
                        input_tokens: seq.req.input_tokens,
                        output_tokens: seq.req.output_tokens,
                        ttft,
                        tpot,
                        finish: now,
                    });
                } else {
                    scratch.push(seq);
                }
            }
            std::mem::swap(&mut inst.batch, &mut scratch);
            self.batch_scratch = scratch;
        }
        self.tokens_since_sample += produced;

        for idx in 0..self.completions_buf.len() {
            let c = self.completions_buf[idx];
            self.ttft_points.push((c.arrival, c.ttft));
            self.coordinator.observe_completion(now, &c);
            self.metrics.record(c);
            if let Some(ck) = self.clocks.remove(&c.id) {
                if let Some(done) = ck.prefill_done {
                    self.metrics.prefill_waits.push((c.arrival, done - c.arrival));
                }
                if let Some(started) = ck.prefill_started {
                    self.metrics.queue_waits.push((c.arrival, started - c.arrival));
                }
            }
        }

        if freed {
            self.retry_awaiting_decode();
        }
        self.ensure_iterating(id);
    }

    fn control_tick(&mut self) {
        let targets = self.coordinator.scale(self.now, &self.cluster);
        self.apply_scaling(targets);
        self.reoffer_pending();
        self.retry_awaiting_decode();
        self.cluster.sweep_drained(self.now);
    }

    fn apply_scaling(&mut self, t: ScaleTargets) {
        let live = if self.coordinator.live_scaling() {
            Some(0.2)
        } else {
            None
        };
        let t = {
            let tp_p = self.cluster.config.prefill_engine.tp;
            let tp_d = self.cluster.config.decode_engine.tp;
            let conv_gpus = self.cluster.role_gpus(Role::ConvertibleDecoder);
            let budget = self.cluster.config.max_gpus.saturating_sub(conv_gpus);
            let want = t.prefillers * tp_p + t.decoders * tp_d;
            if want > budget && want > 0 {
                let ratio = budget as f64 / want as f64;
                ScaleTargets {
                    prefillers: ((t.prefillers as f64 * ratio).floor() as usize).max(1),
                    decoders: ((t.decoders as f64 * ratio).floor() as usize).max(1),
                }
            } else {
                t
            }
        };
        let cur_p = self.cluster.active_count(Role::Prefiller);
        if t.prefillers > cur_p {
            for _ in 0..(t.prefillers - cur_p) {
                if let Some(id) = self.cluster.spawn(Role::Prefiller, self.now, live) {
                    self.scale_ups += 1;
                    let ready = self.cluster.get(id).unwrap().ready_at;
                    self.events.push(ready, Event::InstanceReady { instance: id });
                }
            }
        } else if t.prefillers < cur_p {
            let mut candidates: Vec<(usize, InstanceId)> = self
                .cluster
                .iter_role(Role::Prefiller)
                .filter(|i| i.life != LifeState::Draining)
                .map(|i| (i.inflight_prefill_tokens(), i.id))
                .collect();
            candidates.sort();
            for (_, id) in candidates.into_iter().take(cur_p - t.prefillers) {
                self.cluster.retire(id, self.now);
                self.scale_downs += 1;
            }
        }
        let cur_d = self.cluster.active_count(Role::Decoder);
        if t.decoders > cur_d {
            for _ in 0..(t.decoders - cur_d) {
                if let Some(id) = self.cluster.spawn(Role::Decoder, self.now, live) {
                    self.scale_ups += 1;
                    let ready = self.cluster.get(id).unwrap().ready_at;
                    self.events.push(ready, Event::InstanceReady { instance: id });
                }
            }
        } else if t.decoders < cur_d {
            let mut candidates: Vec<(usize, InstanceId)> = self
                .cluster
                .iter_role(Role::Decoder)
                .filter(|i| i.life != LifeState::Draining)
                .map(|i| (i.decode_load(), i.id))
                .collect();
            candidates.sort();
            for (_, id) in candidates.into_iter().take(cur_d - t.decoders) {
                self.cluster.retire(id, self.now);
                self.scale_downs += 1;
            }
        }
    }

    fn reoffer_pending(&mut self) {
        let n = self.pending.len();
        for _ in 0..n {
            let Some(req) = self.pending.pop_front() else {
                break;
            };
            match self.coordinator.route_prefill(self.now, &req, &self.cluster) {
                Route::Prefiller(id) => {
                    let job = PrefillJob {
                        remaining: req.input_tokens,
                        req,
                        enqueued_at: self.now,
                        chunk_override: None,
                    };
                    if let Some(inst) = self.cluster.get_mut(id) {
                        inst.prefill_queue.push_back(job);
                        self.maybe_start_prefill(id);
                    } else {
                        self.pending.push_back(job.req);
                    }
                }
                Route::Convertible(id) => self.admit_convertible_prefill(id, req),
                Route::Queue => self.pending.push_back(req),
            }
        }
    }

    fn retry_awaiting_decode(&mut self) {
        let n = self.awaiting_decode.len();
        for _ in 0..n {
            let Some(req) = self.awaiting_decode.pop_front() else {
                break;
            };
            self.try_send_to_decoder(req);
        }
    }

    fn sample(&mut self) {
        let t = self.now;
        let mut n_p = 0usize;
        let mut busy = 0usize;
        for i in self.cluster.running_of(Role::Prefiller) {
            n_p += 1;
            busy += i.active_prefill.is_some() as usize;
        }
        let p_util = if n_p == 0 {
            0.0
        } else {
            busy as f64 / n_p as f64
        };
        let mut n_d = 0usize;
        let mut mem_sum = 0.0;
        let mut d_iter = 0usize;
        for i in self
            .cluster
            .running_of(Role::Decoder)
            .chain(self.cluster.running_of(Role::ConvertibleDecoder))
        {
            n_d += 1;
            mem_sum += i.mem_utilization();
            d_iter += i.iterating as usize;
        }
        let mem = if n_d == 0 { 0.0 } else { mem_sum / n_d as f64 };
        let d_busy = if n_d == 0 {
            0.0
        } else {
            d_iter as f64 / n_d as f64
        };
        let net_util = (self.net_bytes_per_s / self.cfg.link.eff_rdma_bytes()).min(1.0);

        self.series.prefill_compute.push(t, p_util);
        self.series.decode_memory.push(t, mem);
        self.series.decode_compute.push(t, d_busy);
        self.series.network.push(t, net_util);
        let elapsed = t - self.last_sample_t;
        let thr = if elapsed > 0.0 {
            self.tokens_since_sample / elapsed
        } else {
            0.0
        };
        self.tokens_since_sample = 0.0;
        self.last_sample_t = t;
        self.series.decode_throughput.push(t, thr);
        self.series
            .queue_len
            .push(t, (self.pending.len() + self.awaiting_decode.len()) as f64);
    }
}

/// v1 convenience wrapper over a materialized trace.
pub fn simulate_legacy<C: Coordinator>(
    cfg: SimConfig,
    cluster_cfg: ClusterConfig,
    coordinator: &mut C,
    trace: &Trace,
) -> SimResult {
    let mut src = TraceSliceSource::new(trace);
    LegacySimEngine::new(cfg, cluster_cfg, coordinator, &mut src).run()
}

/// v1 convenience wrapper over a streaming source.
pub fn simulate_source_legacy<C: Coordinator>(
    cfg: SimConfig,
    cluster_cfg: ClusterConfig,
    coordinator: &mut C,
    arrivals: &mut dyn ArrivalSource,
) -> SimResult {
    LegacySimEngine::new(cfg, cluster_cfg, coordinator, arrivals).run()
}
