//! Discrete-event simulator of a PD-disaggregated LLM serving cluster.
//!
//! Substitute for the paper's physical GPU testbed (see DESIGN.md): the
//! same control planes (TokenScale + baselines) are driven over simulated
//! prefillers, decoders, KVC transfers and instance lifecycles whose
//! timings come from `perfmodel`.

pub mod cluster;
pub mod engine;
pub mod event;
pub mod instance;
pub mod policy;

pub use cluster::{Cluster, ClusterConfig};
pub use engine::{simulate, simulate_source, SimConfig, SimEngine, SimResult, SimSeries};
pub use event::{Event, EventQueue, InstanceId};
pub use instance::{ActiveSeq, Instance, LifeState, PrefillJob, RequestClock, Role};
pub use policy::{Coordinator, Route, ScaleTargets, StaticCoordinator};
