//! Discrete-event simulator of a PD-disaggregated LLM serving cluster.
//!
//! Substitute for the paper's physical GPU testbed (see DESIGN.md): the
//! same control planes (TokenScale + baselines) are driven over simulated
//! prefillers, decoders, KVC transfers and instance lifecycles whose
//! timings come from `perfmodel`.
//!
//! Control planes implement the action-based [`ControlPlane`] v2 API
//! (docs/control_plane.md): the engine delivers typed [`Signal`]s with a
//! read-only [`ClusterView`], policies answer with typed [`Action`]s, and
//! the engine validates, applies and audits them. (The pre-redesign
//! `Coordinator` trait and its frozen v1 engine were deleted after the
//! v1→v2 equivalence gate ran its course in PR 3; the surviving
//! determinism assertions live in `rust/tests/control_plane_equivalence.rs`.)

pub mod audit;
pub mod cluster;
pub mod engine;
pub mod event;
pub mod faults;
pub mod instance;
pub mod kvcache;
pub mod policy;
pub mod reqtable;
pub mod snapshot;
pub mod view;

pub use audit::{DecisionLog, DecisionRecord};
pub use cluster::{Cluster, ClusterConfig, FailureRecord};
pub use faults::{FaultKind, FaultLabel, FaultPlan, FaultSchedule, FaultSpec};
pub use engine::{simulate, simulate_source, SimConfig, SimEngine, SimResult, SimSeries};
pub use event::{Event, EventQueue, InstanceId};
pub use instance::{ActiveSeq, Instance, LifeState, PrefillJob, RequestClock, Role};
pub use kvcache::{CacheLookup, KvCacheConfig, PrefixCache};
pub use policy::{
    Action, ActionOutcome, ControlPlane, RejectReason, Signal, SignalKind, StaticCoordinator,
};
pub use snapshot::{PolicyState, SimSnapshot, SNAPSHOT_SCHEMA_VERSION};
pub use view::ClusterView;
