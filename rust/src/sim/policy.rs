//! The control-plane interface the simulator drives — **ControlPlane v2**.
//!
//! The simulator owns the *mechanics* (queues, batches, transfers, memory,
//! clocks); a [`ControlPlane`] owns the *decisions*. Where the old
//! `Coordinator` trait (deleted after its frozen copy served one PR as
//! the v1→v2 equivalence oracle) could only answer two fixed questions —
//! "where does this prefill go?" and "how many instances do you want?" —
//! v2 inverts the boundary into a command API:
//!
//! - the engine delivers typed [`Signal`]s (arrivals, prefill/decode
//!   hand-offs, control ticks, instance lifecycle notifications) together
//!   with a read-only [`ClusterView`](super::view::ClusterView);
//! - the policy answers with a list of typed [`Action`]s;
//! - the engine *validates* and *interprets* every action: invalid ones
//!   become typed [`RejectReason`]s counted in
//!   [`MetricsRecorder`](crate::metrics::MetricsRecorder) and surfaced in
//!   `SloReport::rejected_actions`, and every decision is appended to the
//!   optional [`DecisionLog`](super::audit::DecisionLog) ring buffer
//!   (`tokenscale explain` prints it).
//!
//! This makes decisions the old API hard-wired or could not express —
//! draining one specific instance, converting a decoder on the fly
//! (§III-D), or deflecting a prefill onto a *regular* decoder (load-aware
//! prefill deflection) — first-class policy moves, while every policy
//! still runs on identical mechanics.

use super::event::InstanceId;
use super::faults::FaultLabel;
use super::instance::Role;
use super::snapshot::PolicyState;
use super::view::ClusterView;
use crate::workload::{BucketScheme, Completion, Request, RequestId};

/// What the engine tells a control plane. Borrowed payloads: signals are
/// delivered synchronously from the event loop.
#[derive(Clone, Copy, Debug)]
pub enum Signal<'a> {
    /// A fresh request reached the gateway. Expected answer: one
    /// [`Action::RoutePrefill`] or [`Action::DeflectPrefill`]; no routing
    /// action queues the request at the gateway (Alg. 1 line 15).
    Arrival(&'a Request),
    /// A gateway-queued request is re-offered (control tick / instance
    /// ready). Same expected answers as [`Signal::Arrival`], but traffic
    /// windows must NOT be updated again.
    RetryPrefill(&'a Request),
    /// A request's prefill finished (or a backpressured request retries);
    /// its KVC needs a decoder. Expected answer: one
    /// [`Action::DispatchDecode`]; none = backpressure, the engine retries
    /// later.
    PrefillDone(&'a Request),
    /// A request completed and freed its KV memory.
    Completion(&'a Completion),
    /// Periodic control tick (autoscaler evaluation). Fleet-shaping
    /// actions ([`Action::SetFleet`], [`Action::Convert`], …) usually
    /// answer this.
    Tick,
    /// A provisioned instance finished starting up.
    InstanceReady(InstanceId),
    /// A draining instance finished its work and left the cluster.
    InstanceDrained(InstanceId),
    /// An instance was lost to an injected fault. `planned` is true for
    /// preemptions (a drain warning preceded the loss), false for
    /// crashes. Recovery is a *policy* decision: re-scale, convert a
    /// decoder, deflect — the engine only salvages the lost requests
    /// back into the gateway.
    InstanceFailed { instance: InstanceId, planned: bool },
}

impl Signal<'_> {
    /// Payload-free tag for audit records.
    pub fn kind(&self) -> SignalKind {
        match self {
            Signal::Arrival(_) => SignalKind::Arrival,
            Signal::RetryPrefill(_) => SignalKind::RetryPrefill,
            Signal::PrefillDone(_) => SignalKind::PrefillDone,
            Signal::Completion(_) => SignalKind::Completion,
            Signal::Tick => SignalKind::Tick,
            Signal::InstanceReady(_) => SignalKind::InstanceReady,
            Signal::InstanceDrained(_) => SignalKind::InstanceDrained,
            Signal::InstanceFailed { .. } => SignalKind::InstanceFailed,
        }
    }
}

/// Payload-free [`Signal`] tag (audit trail, summaries).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SignalKind {
    Arrival,
    RetryPrefill,
    PrefillDone,
    Completion,
    Tick,
    InstanceReady,
    InstanceDrained,
    InstanceFailed,
}

impl SignalKind {
    pub fn label(self) -> &'static str {
        match self {
            SignalKind::Arrival => "arrival",
            SignalKind::RetryPrefill => "retry-prefill",
            SignalKind::PrefillDone => "prefill-done",
            SignalKind::Completion => "completion",
            SignalKind::Tick => "tick",
            SignalKind::InstanceReady => "instance-ready",
            SignalKind::InstanceDrained => "instance-drained",
            SignalKind::InstanceFailed => "instance-failed",
        }
    }
}

/// A typed command from the control plane to the cluster. The engine
/// validates each action against the current cluster state; invalid
/// actions are rejected with a [`RejectReason`] instead of silently
/// corrupting mechanics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Action {
    /// Send `req`'s prefill to a prefiller or (chunked, in-place) to a
    /// Convertible Decoder. Rejected: unknown instance, regular decoder
    /// target (use [`Action::DeflectPrefill`]), or a request id that is
    /// not the one the signal carried.
    RoutePrefill { req: RequestId, target: InstanceId },
    /// Run `req`'s prefill on a *regular* decoder (load-aware prefill
    /// deflection). `chunked` interleaves it with decode iterations at the
    /// deployment chunk budget; otherwise the prompt runs as a single
    /// restricted-chunked pass. Rejected when the decoder lacks the KV
    /// reserve capacity for the request's full footprint.
    DeflectPrefill {
        req: RequestId,
        decoder: InstanceId,
        chunked: bool,
    },
    /// Ship `req`'s KVC to `decoder` and join its continuous batch.
    /// `bucket` is the predicted request-type bucket recorded on the
    /// sequence for per-type load balancing.
    DispatchDecode {
        req: RequestId,
        decoder: InstanceId,
        bucket: usize,
    },
    /// Desired instance count for one role. Prefiller and Decoder targets
    /// given in the same signal dispatch share the GPU quota exactly like
    /// the old `ScaleTargets` (proportional shrink when over budget —
    /// recorded as a clamped [`RejectReason::FleetOverQuota`]).
    /// ConvertibleDecoder targets spawn/retire the convertible pool.
    SetFleet { role: Role, target: usize },
    /// Turn a regular decoder into a Convertible Decoder (grants it the
    /// deployment chunk budget + Eq. 6 reserve). Rejected on non-decoders.
    Convert { decoder: InstanceId },
    /// Turn a Convertible Decoder back into a regular decoder. Rejected
    /// while it still holds prefill work.
    Revert { decoder: InstanceId },
    /// Begin draining one specific instance; it finishes queued work and
    /// is removed once idle. Rejected if already draining.
    Drain { instance: InstanceId },
    /// Engine-originated audit verb: an injected fault hit `instance`.
    /// Never valid from a policy — policies emitting it get
    /// [`RejectReason::EngineOnly`]; the engine records it directly in
    /// the decision ring so `tokenscale explain` shows cause→reaction
    /// chains.
    Fault {
        instance: InstanceId,
        kind: FaultLabel,
    },
}

impl Action {
    pub fn label(&self) -> &'static str {
        match self {
            Action::RoutePrefill { .. } => "route-prefill",
            Action::DeflectPrefill { .. } => "deflect-prefill",
            Action::DispatchDecode { .. } => "dispatch-decode",
            Action::SetFleet { .. } => "set-fleet",
            Action::Convert { .. } => "convert",
            Action::Revert { .. } => "revert",
            Action::Drain { .. } => "drain",
            Action::Fault { .. } => "fault",
        }
    }
}

impl std::fmt::Display for Action {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Action::RoutePrefill { req, target } => write!(f, "RoutePrefill(req {req} -> {target})"),
            Action::DeflectPrefill { req, decoder, chunked } => {
                write!(f, "DeflectPrefill(req {req} -> {decoder}, chunked={chunked})")
            }
            Action::DispatchDecode { req, decoder, bucket } => {
                write!(f, "DispatchDecode(req {req} -> {decoder}, bucket {bucket})")
            }
            Action::SetFleet { role, target } => write!(f, "SetFleet({role:?} -> {target})"),
            Action::Convert { decoder } => write!(f, "Convert({decoder})"),
            Action::Revert { decoder } => write!(f, "Revert({decoder})"),
            Action::Drain { instance } => write!(f, "Drain({instance})"),
            Action::Fault { instance, kind } => {
                write!(f, "Fault({instance}, {})", kind.label())
            }
        }
    }
}

/// Why the engine refused (or clamped) an action.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The referenced instance does not exist (stale id).
    UnknownInstance,
    /// The action names a request other than the one the signal carried.
    UnknownRequest,
    /// The instance's role cannot perform this action (e.g. `Convert` on
    /// a prefiller, `DeflectPrefill` to a non-decoder).
    WrongRole,
    /// The instance is not running (still starting).
    NotRunning,
    /// The target lacks KV reserve capacity for the request.
    NoCapacity,
    /// The combined fleet target exceeds `max_gpus`; targets were
    /// proportionally clamped (old quota-sharing semantics).
    FleetOverQuota,
    /// `Drain` of an instance that is already draining.
    AlreadyDraining,
    /// `Revert` of a convertible that still holds prefill work.
    Busy,
    /// A second routing action for a request that was already consumed in
    /// this dispatch.
    DuplicateRoute,
    /// A policy emitted an engine-originated audit verb
    /// ([`Action::Fault`]).
    EngineOnly,
}

impl RejectReason {
    pub const ALL: [RejectReason; 10] = [
        RejectReason::UnknownInstance,
        RejectReason::UnknownRequest,
        RejectReason::WrongRole,
        RejectReason::NotRunning,
        RejectReason::NoCapacity,
        RejectReason::FleetOverQuota,
        RejectReason::AlreadyDraining,
        RejectReason::Busy,
        RejectReason::DuplicateRoute,
        RejectReason::EngineOnly,
    ];

    /// Dense index for counter arrays.
    pub fn idx(self) -> usize {
        match self {
            RejectReason::UnknownInstance => 0,
            RejectReason::UnknownRequest => 1,
            RejectReason::WrongRole => 2,
            RejectReason::NotRunning => 3,
            RejectReason::NoCapacity => 4,
            RejectReason::FleetOverQuota => 5,
            RejectReason::AlreadyDraining => 6,
            RejectReason::Busy => 7,
            RejectReason::DuplicateRoute => 8,
            RejectReason::EngineOnly => 9,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            RejectReason::UnknownInstance => "unknown-instance",
            RejectReason::UnknownRequest => "unknown-request",
            RejectReason::WrongRole => "wrong-role",
            RejectReason::NotRunning => "not-running",
            RejectReason::NoCapacity => "no-capacity",
            RejectReason::FleetOverQuota => "fleet-over-quota",
            RejectReason::AlreadyDraining => "already-draining",
            RejectReason::Busy => "busy",
            RejectReason::DuplicateRoute => "duplicate-route",
            RejectReason::EngineOnly => "engine-only",
        }
    }
}

/// What happened to one action after validation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ActionOutcome {
    Applied,
    /// Applied after adjustment (fleet quota sharing).
    Clamped(RejectReason),
    Rejected(RejectReason),
}

impl ActionOutcome {
    pub fn reject_reason(&self) -> Option<RejectReason> {
        match self {
            ActionOutcome::Applied => None,
            ActionOutcome::Clamped(r) | ActionOutcome::Rejected(r) => Some(*r),
        }
    }
}

/// A serving control plane: gateway statistics, router, load balancer and
/// autoscaler, driven by the simulator's event loop through typed signals
/// and answering with typed actions.
pub trait ControlPlane {
    fn name(&self) -> &str;

    /// React to one signal. Push any number of [`Action`]s; the engine
    /// validates and applies them in order. The view is a read-only
    /// snapshot of the cluster at signal time.
    fn on_signal(
        &mut self,
        now: f64,
        signal: Signal<'_>,
        view: &ClusterView<'_>,
        actions: &mut Vec<Action>,
    );

    /// Whether scale-ups use live autoscaling (BlitzScale §V: scale-up
    /// executed proactively, removing model-load latency).
    fn live_scaling(&self) -> bool {
        false
    }

    /// Serialize policy-internal state for checkpointing (the
    /// `sim::snapshot` hook). Stateful policies override this to capture
    /// their traffic windows, hysteresis streaks and RNG positions
    /// bit-exactly; the default declares the policy stateless.
    fn save_state(&self) -> PolicyState {
        PolicyState::stateless(self.name())
    }

    /// Restore state captured by [`ControlPlane::save_state`] into a
    /// freshly constructed instance of the *same* policy (construction
    /// parameters are re-derived from the experiment spec; only stream
    /// state travels through the snapshot). The default verifies the
    /// snapshot names this policy and restores nothing.
    fn restore_state(&mut self, state: &PolicyState) -> anyhow::Result<()> {
        state.expect(self.name())
    }
}

/// A fixed-fleet control plane used for tests, profiling sweeps and the
/// "required vs provisioned" ground-truth runs: never scales, routes
/// prefill to the least-loaded prefiller and decode to the least-loaded
/// decoder with capacity.
pub struct StaticCoordinator {
    pub prefillers: usize,
    pub decoders: usize,
    /// Cached classification scheme (one per policy, not one per call).
    scheme: BucketScheme,
}

impl StaticCoordinator {
    pub fn new(prefillers: usize, decoders: usize) -> Self {
        StaticCoordinator {
            prefillers,
            decoders,
            scheme: BucketScheme::default(),
        }
    }

    fn route_prefill(&self, view: &ClusterView<'_>) -> Option<InstanceId> {
        view.running_of(Role::Prefiller)
            .min_by_key(|i| i.inflight_prefill_tokens())
            .map(|i| i.id)
    }

    fn route_decode(&self, req: &Request, view: &ClusterView<'_>) -> Option<InstanceId> {
        view.running_of(Role::Decoder)
            .chain(view.running_of(Role::ConvertibleDecoder))
            .filter(|i| i.can_admit(req.total_tokens()))
            .min_by_key(|i| i.decode_load())
            .map(|i| i.id)
    }
}

impl ControlPlane for StaticCoordinator {
    fn name(&self) -> &str {
        "static"
    }

    fn on_signal(
        &mut self,
        _now: f64,
        signal: Signal<'_>,
        view: &ClusterView<'_>,
        actions: &mut Vec<Action>,
    ) {
        match signal {
            Signal::Arrival(req) | Signal::RetryPrefill(req) => {
                if let Some(target) = self.route_prefill(view) {
                    actions.push(Action::RoutePrefill { req: req.id, target });
                }
            }
            Signal::PrefillDone(req) => {
                if let Some(decoder) = self.route_decode(req, view) {
                    let bucket = self
                        .scheme
                        .classify(req.input_tokens, req.output_tokens)
                        .index();
                    actions.push(Action::DispatchDecode {
                        req: req.id,
                        decoder,
                        bucket,
                    });
                }
            }
            Signal::Tick => {
                actions.push(Action::SetFleet {
                    role: Role::Prefiller,
                    target: self.prefillers,
                });
                actions.push(Action::SetFleet {
                    role: Role::Decoder,
                    target: self.decoders,
                });
            }
            Signal::Completion(_)
            | Signal::InstanceReady(_)
            | Signal::InstanceDrained(_)
            | Signal::InstanceFailed { .. } => {}
        }
    }
}
