//! The control-plane interface the simulator drives.
//!
//! The simulator owns the *mechanics* (queues, batches, transfers, memory,
//! clocks); a [`Coordinator`] owns the *decisions* (routing, load
//! balancing, autoscaling). TokenScale and every baseline implement this
//! trait, so all systems are compared on identical mechanics — mirroring
//! how the paper deploys different control planes over the same vLLM
//! cluster.

use super::cluster::Cluster;
use super::event::InstanceId;
use crate::workload::{Completion, Request};

/// Where a request's prefill should execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// A regular prefiller instance.
    Prefiller(InstanceId),
    /// A Convertible Decoder running restricted chunked prefill (§III-D).
    Convertible(InstanceId),
    /// No feasible instance: wait in the gateway queue (Alg. 1 line 15).
    Queue,
}

/// Desired instance counts from an autoscaler evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScaleTargets {
    pub prefillers: usize,
    /// Regular decoders (convertible decoders are statically provisioned
    /// and never scaled, per §IV-C2).
    pub decoders: usize,
}

/// A serving control plane: gateway statistics, router, load balancer and
/// autoscaler, driven by the simulator's event loop.
pub trait Coordinator {
    fn name(&self) -> &str;

    /// Gateway ingest notification: called once per request on arrival,
    /// before routing. Policies maintain their traffic windows here.
    fn observe_arrival(&mut self, now: f64, req: &Request);

    /// Route a prefill task (fresh arrival or queued retry).
    fn route_prefill(&mut self, now: f64, req: &Request, cluster: &Cluster) -> Route;

    /// Pick a decoder to receive the KVC of a prefilled request.
    /// `None` = all decoders saturated (backpressure; the engine retries).
    fn route_decode(&mut self, now: f64, req: &Request, cluster: &Cluster) -> Option<InstanceId>;

    /// Autoscaler evaluation at a control tick.
    fn scale(&mut self, now: f64, cluster: &Cluster) -> ScaleTargets;

    /// Predicted request-type bucket index (0..9) used for per-type load
    /// balancing and the decoder autoscaler.
    fn predict_bucket(&mut self, req: &Request) -> usize;

    /// Whether scale-ups use live autoscaling (BlitzScale §V: scale-up
    /// executed proactively, removing model-load latency).
    fn live_scaling(&self) -> bool {
        false
    }

    /// Notification that a completion happened (memory freed) — lets
    /// policies track decode velocity online. Receives the completion
    /// record directly (the engine no longer reconstructs a `Request` per
    /// completion on the hot path).
    fn observe_completion(&mut self, _now: f64, _completion: &Completion) {}
}

/// A fixed-fleet coordinator used for tests, profiling sweeps and the
/// "required vs provisioned" ground-truth runs: never scales, routes
/// prefill to the least-loaded prefiller and decode to the least-loaded
/// decoder with capacity.
pub struct StaticCoordinator {
    pub prefillers: usize,
    pub decoders: usize,
}

impl StaticCoordinator {
    pub fn new(prefillers: usize, decoders: usize) -> Self {
        StaticCoordinator {
            prefillers,
            decoders,
        }
    }
}

impl Coordinator for StaticCoordinator {
    fn name(&self) -> &str {
        "static"
    }

    fn observe_arrival(&mut self, _now: f64, _req: &Request) {}

    fn route_prefill(&mut self, _now: f64, _req: &Request, cluster: &Cluster) -> Route {
        use super::instance::Role;
        cluster
            .running_of(Role::Prefiller)
            .min_by_key(|i| i.inflight_prefill_tokens())
            .map(|i| Route::Prefiller(i.id))
            .unwrap_or(Route::Queue)
    }

    fn route_decode(&mut self, _now: f64, req: &Request, cluster: &Cluster) -> Option<InstanceId> {
        use super::instance::Role;
        cluster
            .running_of(Role::Decoder)
            .chain(cluster.running_of(Role::ConvertibleDecoder))
            .filter(|i| i.can_admit(req.total_tokens()))
            .min_by_key(|i| i.decode_load())
            .map(|i| i.id)
    }

    fn scale(&mut self, _now: f64, _cluster: &Cluster) -> ScaleTargets {
        ScaleTargets {
            prefillers: self.prefillers,
            decoders: self.decoders,
        }
    }

    fn predict_bucket(&mut self, req: &Request) -> usize {
        crate::workload::BucketScheme::default()
            .classify(req.input_tokens, req.output_tokens)
            .index()
    }
}
