//! Slab-allocated per-request state arena.
//!
//! The engine used to key four separate `HashMap<RequestId, _>`s (clock,
//! in-flight transfer, transfer payload, fault-cohort membership) — four
//! SipHash probes and an allocator round-trip per request. [`ReqTable`]
//! replaces them with one dense slab: each live request owns a single
//! reusable slot found through a compact open-addressed index
//! ([`U64Map`], Fibonacci hashing, `u32` slot handles). Slots return to a
//! free list on release, so steady-state operation allocates nothing.
//!
//! Determinism: iteration order is slot order (insertion-and-reuse
//! dependent), so callers that serialize the table must sort by request
//! id — exactly what the engine's checkpoint writer already did for the
//! `HashMap`s it replaces.

const EMPTY: u32 = u32::MAX;
const TOMB: u32 = u32::MAX - 1;

/// Open-addressed `u64 -> u32` index: linear probing over a power-of-two
/// table, tombstone deletion, Fibonacci-multiply hashing. Values must be
/// `< u32::MAX - 1` (the two top values are control sentinels) — slot
/// handles, in practice.
struct U64Map {
    keys: Vec<u64>,
    vals: Vec<u32>,
    /// Live entries.
    live: usize,
    /// Live entries plus tombstones (controls growth/rehash).
    used: usize,
}

impl U64Map {
    fn new() -> U64Map {
        U64Map {
            keys: vec![0; 64],
            vals: vec![EMPTY; 64],
            live: 0,
            used: 0,
        }
    }

    #[inline]
    fn mask(&self) -> usize {
        self.keys.len() - 1
    }

    #[inline]
    fn start(&self, key: u64) -> usize {
        // Fibonacci hashing: the high bits of the multiply are well mixed;
        // shift them down to the table's index width.
        let h = key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        (h >> (64 - self.keys.len().trailing_zeros())) as usize & self.mask()
    }

    fn get(&self, key: u64) -> Option<u32> {
        let mask = self.mask();
        let mut i = self.start(key);
        loop {
            let v = self.vals[i];
            if v == EMPTY {
                return None;
            }
            if v != TOMB && self.keys[i] == key {
                return Some(v);
            }
            i = (i + 1) & mask;
        }
    }

    /// Insert or overwrite. `val` must be below the sentinel range.
    fn insert(&mut self, key: u64, val: u32) {
        debug_assert!(val < TOMB, "value collides with control sentinel");
        // Keep load (incl. tombstones) under 3/4 so probes terminate.
        if (self.used + 1) * 4 >= self.keys.len() * 3 {
            self.grow();
        }
        let mask = self.mask();
        let mut i = self.start(key);
        let mut first_tomb: Option<usize> = None;
        loop {
            let v = self.vals[i];
            if v == EMPTY {
                let slot = first_tomb.unwrap_or(i);
                // A reclaimed tombstone does not raise `used`.
                if first_tomb.is_none() {
                    self.used += 1;
                }
                self.keys[slot] = key;
                self.vals[slot] = val;
                self.live += 1;
                return;
            }
            if v == TOMB {
                if first_tomb.is_none() {
                    first_tomb = Some(i);
                }
            } else if self.keys[i] == key {
                self.vals[i] = val;
                return;
            }
            i = (i + 1) & mask;
        }
    }

    fn remove(&mut self, key: u64) -> Option<u32> {
        let mask = self.mask();
        let mut i = self.start(key);
        loop {
            let v = self.vals[i];
            if v == EMPTY {
                return None;
            }
            if v != TOMB && self.keys[i] == key {
                self.vals[i] = TOMB;
                self.live -= 1;
                return Some(v);
            }
            i = (i + 1) & mask;
        }
    }

    /// Rehash into a table sized for the live count (doubling when
    /// genuinely full, merely purging tombstones when churn-dominated).
    fn grow(&mut self) {
        let want = if (self.live + 1) * 2 >= self.keys.len() {
            self.keys.len() * 2
        } else {
            self.keys.len()
        };
        let old_keys = std::mem::replace(&mut self.keys, vec![0; want]);
        let old_vals = std::mem::replace(&mut self.vals, vec![EMPTY; want]);
        self.live = 0;
        self.used = 0;
        for (k, v) in old_keys.into_iter().zip(old_vals) {
            if v != EMPTY && v != TOMB {
                self.insert(k, v);
            }
        }
    }
}

/// Dense per-key arena: one reusable slot per live key, a free list for
/// O(1) recycling, and a [`U64Map`] index for key lookup.
pub struct ReqTable<T> {
    slots: Vec<Option<(u64, T)>>,
    free: Vec<u32>,
    index: U64Map,
    len: usize,
}

impl<T> Default for ReqTable<T> {
    fn default() -> Self {
        ReqTable::new()
    }
}

impl<T> ReqTable<T> {
    pub fn new() -> ReqTable<T> {
        ReqTable {
            slots: Vec::new(),
            free: Vec::new(),
            index: U64Map::new(),
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn get(&self, key: u64) -> Option<&T> {
        let i = self.index.get(key)?;
        self.slots[i as usize].as_ref().map(|(_, v)| v)
    }

    pub fn get_mut(&mut self, key: u64) -> Option<&mut T> {
        let i = self.index.get(key)?;
        self.slots[i as usize].as_mut().map(|(_, v)| v)
    }

    /// The slot for `key`, created from `T::default()` if absent.
    pub fn entry(&mut self, key: u64) -> &mut T
    where
        T: Default,
    {
        let i = match self.index.get(key) {
            Some(i) => i,
            None => {
                let i = match self.free.pop() {
                    Some(i) => {
                        self.slots[i as usize] = Some((key, T::default()));
                        i
                    }
                    None => {
                        assert!(
                            self.slots.len() < (TOMB as usize),
                            "ReqTable slot handles exhausted"
                        );
                        self.slots.push(Some((key, T::default())));
                        (self.slots.len() - 1) as u32
                    }
                };
                self.index.insert(key, i);
                self.len += 1;
                i
            }
        };
        match &mut self.slots[i as usize] {
            Some((_, v)) => v,
            None => unreachable!("index points at a vacant slot"),
        }
    }

    /// Remove `key`, returning its state and recycling the slot.
    pub fn remove(&mut self, key: u64) -> Option<T> {
        let i = self.index.remove(key)?;
        let (_, v) = self.slots[i as usize]
            .take()
            .expect("index points at a live slot");
        self.free.push(i);
        self.len -= 1;
        Some(v)
    }

    /// Iterate live entries in slot order (NOT key order — sort before
    /// serializing).
    pub fn iter(&self) -> impl Iterator<Item = (u64, &T)> + '_ {
        self.slots
            .iter()
            .filter_map(|s| s.as_ref().map(|(k, v)| (*k, v)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use std::collections::HashMap;

    #[test]
    fn basic_insert_get_remove() {
        let mut t: ReqTable<u64> = ReqTable::new();
        assert!(t.is_empty());
        *t.entry(7) = 70;
        *t.entry(0) = 1;
        *t.entry(u64::MAX) = 2;
        assert_eq!(t.len(), 3);
        assert_eq!(t.get(7), Some(&70));
        assert_eq!(t.get(0), Some(&1));
        assert_eq!(t.get(u64::MAX), Some(&2));
        assert_eq!(t.get(8), None);
        assert_eq!(t.remove(7), Some(70));
        assert_eq!(t.get(7), None);
        assert_eq!(t.len(), 2);
        // Entry on an existing key returns the same slot, not a fresh one.
        *t.entry(0) += 10;
        assert_eq!(t.get(0), Some(&11));
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn slots_are_recycled_through_the_free_list() {
        let mut t: ReqTable<u64> = ReqTable::new();
        for k in 0..100u64 {
            *t.entry(k) = k;
        }
        for k in 0..100u64 {
            assert_eq!(t.remove(k), Some(k));
        }
        let high_water = t.slots.len();
        // A second wave of 100 must reuse the freed slots exactly.
        for k in 1000..1100u64 {
            *t.entry(k) = k;
        }
        assert_eq!(t.slots.len(), high_water, "free-list reuse, no growth");
        assert_eq!(t.len(), 100);
    }

    #[test]
    fn iter_visits_every_live_entry_once() {
        let mut t: ReqTable<u64> = ReqTable::new();
        for k in [5u64, 1, 9, 3] {
            *t.entry(k) = k * 2;
        }
        t.remove(9);
        let mut seen: Vec<(u64, u64)> = t.iter().map(|(k, v)| (k, *v)).collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![(1, 2), (3, 6), (5, 10)]);
    }

    /// What the engine's checkpoint writer does with the arena: collect
    /// live entries and sort by request id so the serialized bytes are
    /// independent of slot-reuse order (engine.rs `checkpoint`).
    fn sorted_dump(t: &ReqTable<u64>) -> Vec<(u64, u64)> {
        let mut out: Vec<(u64, u64)> = t.iter().map(|(k, v)| (k, *v)).collect();
        out.sort_by_key(|(k, _)| *k);
        out
    }

    #[test]
    fn checkpoint_writer_sees_nothing_after_full_drain() {
        // Empty-queue path: a drained table must serialize exactly like a
        // never-used one — no ghost entries from recycled slots, and the
        // index (now tombstone-riddled) must still terminate lookups.
        let mut drained: ReqTable<u64> = ReqTable::new();
        for k in 0..64u64 {
            *drained.entry(k) = k;
        }
        for k in 0..64u64 {
            drained.remove(k);
        }
        let fresh: ReqTable<u64> = ReqTable::new();
        assert!(drained.is_empty());
        assert_eq!(sorted_dump(&drained), sorted_dump(&fresh));
        assert_eq!(drained.iter().count(), 0);
        assert_eq!(drained.get(3), None, "tombstoned key stays gone");
        assert_eq!(drained.get(999), None, "probe past tombstones terminates");
        // The drained table is still fully usable afterwards.
        *drained.entry(7) = 70;
        assert_eq!(sorted_dump(&drained), vec![(7, 70)]);
    }

    #[test]
    fn checkpoint_writer_is_order_independent_under_tombstone_churn() {
        // Tombstone-heavy path: reach the same logical contents through
        // wildly different insert/remove histories (different slot
        // assignments, different tombstone layouts) and require the
        // sorted dump — the checkpoint bytes — to be identical.
        let keys: Vec<u64> = (0..40u64).map(|k| k * 17 + 3).collect();

        let mut straight: ReqTable<u64> = ReqTable::new();
        for &k in &keys {
            *straight.entry(k) = k ^ 0xABCD;
        }

        let mut churned: ReqTable<u64> = ReqTable::new();
        // Three full waves of decoys interleaved with the real keys, each
        // wave removed again, so every real key lands in a recycled slot
        // behind a different tombstone pattern.
        for wave in 0..3u64 {
            for d in 0..64u64 {
                *churned.entry(1_000_000 + wave * 100 + d) = d;
            }
            for d in 0..64u64 {
                churned.remove(1_000_000 + wave * 100 + d);
            }
        }
        for &k in keys.iter().rev() {
            *churned.entry(k) = 0; // placeholder, overwritten below
        }
        for &k in &keys {
            *churned.entry(k) = k ^ 0xABCD;
        }
        assert_eq!(sorted_dump(&straight), sorted_dump(&churned));
        assert_eq!(churned.len(), keys.len());
    }

    #[test]
    fn tombstone_churn_purges_instead_of_growing_forever() {
        // Sustained insert/remove churn with a tiny live set must not
        // ratchet the index table up: grow() purges tombstones in place
        // when the live count is small.
        let mut t: ReqTable<u64> = ReqTable::new();
        for round in 0..2_000u64 {
            *t.entry(round) = round;
            if round >= 8 {
                t.remove(round - 8);
            }
        }
        assert_eq!(t.len(), 8);
        assert!(
            t.index.keys.len() <= 256,
            "index ratcheted to {} slots for 8 live entries",
            t.index.keys.len()
        );
        assert!(
            t.slots.len() <= 64,
            "slab ratcheted to {} slots for 8 live entries",
            t.slots.len()
        );
        // And the survivors checkpoint correctly.
        let want: Vec<(u64, u64)> = (1_992..2_000).map(|k| (k, k)).collect();
        assert_eq!(sorted_dump(&t), want);
    }

    #[test]
    fn prop_matches_std_hashmap_oracle() {
        // Random insert/overwrite/remove/lookup churn against HashMap,
        // with a skewed key range so collisions and tombstone reuse are
        // constantly exercised.
        prop::check(prop::Config::named("reqtable-vs-hashmap"), |rng| {
            let mut t: ReqTable<u64> = ReqTable::new();
            let mut oracle: HashMap<u64, u64> = HashMap::new();
            let ops = 200 + rng.range_usize(0, 600);
            for step in 0..ops {
                let key = rng.below(96);
                match rng.below(4) {
                    0 | 1 => {
                        let v = step as u64;
                        *t.entry(key) = v;
                        oracle.insert(key, v);
                    }
                    2 => {
                        assert_eq!(t.remove(key), oracle.remove(&key));
                    }
                    _ => {
                        assert_eq!(t.get(key), oracle.get(&key));
                    }
                }
                assert_eq!(t.len(), oracle.len());
            }
            let mut got: Vec<(u64, u64)> = t.iter().map(|(k, v)| (k, *v)).collect();
            got.sort_unstable();
            let mut want: Vec<(u64, u64)> = oracle.into_iter().collect();
            want.sort_unstable();
            assert_eq!(got, want);
        });
    }
}
