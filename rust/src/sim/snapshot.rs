//! Checkpoint/restore of complete simulation state (`SimSnapshot`).
//!
//! A snapshot captures *everything* a mid-run [`SimEngine`] holds — the
//! event heap (with its FIFO tie-break sequence numbers), the slab
//! cluster (instances, free list, generations, per-role live lists), the
//! pending arrival and the stream resume position, every request clock
//! and in-flight transfer, the `MetricsRecorder` accumulators, the
//! decision-audit ring, and the control plane's internal state via the
//! [`PolicyState`] hook on [`ControlPlane`](super::policy::ControlPlane)
//! — such that `SimEngine::resume` continues the run **bit-identically**
//! to one that was never interrupted (`rust/tests/snapshot_equivalence.rs`
//! enforces byte equality of `SloReport`s, completions, event counts and
//! GPU-seconds).
//!
//! ## Encoding
//!
//! Snapshots serialize through the repo's [`Json`] model (util/json.rs),
//! `schema_version`-tagged like the normalized BENCH files. JSON numbers
//! cannot represent the state losslessly (`f64::INFINITY` sentinels,
//! `u64`/`u128` counters past 2^53, and round-trip drift would break bit
//! equality), so every scalar that must survive exactly is encoded as a
//! fixed-width hex string of its bits (`Json::f64_bits`, `Json::u64_hex`,
//! `Json::u128_hex`). Small structural integers (queue lengths, token
//! counts, slot indices) stay plain numbers for readability.
//!
//! ## Stream resume
//!
//! Arrival sources are not serialized: they are deterministic per
//! construction (spec × seed × transform chain), so the snapshot records
//! only how many arrivals were pulled (`arrivals_pulled`) and resume
//! rebuilds the source and [`fast_forward`](crate::trace::fast_forward)s
//! it. The property test in `rust/tests/snapshot_equivalence.rs` pins
//! that any generator+transform stack resumed this way yields the
//! identical arrival suffix.
//!
//! See docs/checkpoints.md for the on-disk format and the warm-start
//! lifecycle built on top (report/runner.rs, report/suite.rs).

use super::audit::{DecisionLog, DecisionRecord};
use super::event::{Event, InstanceId};
use super::faults::FaultLabel;
use super::instance::{ActiveSeq, Instance, LifeState, PrefillJob, Role};
use super::policy::{Action, ActionOutcome, RejectReason, SignalKind};
use crate::perfmodel::EngineModel;
use crate::util::json::Json;
use crate::workload::Request;
use std::sync::Arc;

/// Version tag of the snapshot encoding; bump on any structural change.
/// v2: fault-injection state (request retries, instance perf factor,
/// fault events/actions, transfer attempts, failure ledger, cohorts).
/// v3: prefix-cache state (request session refs, per-job cached tokens,
/// per-instance `sim::kvcache` blob, recorder cache counters).
/// v4: telemetry state (obs span log + timeline blob, `ObsTick` events,
/// decision-record sample stamps).
pub const SNAPSHOT_SCHEMA_VERSION: u64 = 4;

// ------------------------------------------------------------ helpers

pub(crate) fn get<'j>(j: &'j Json, key: &str, what: &str) -> anyhow::Result<&'j Json> {
    j.get(key)
        .ok_or_else(|| anyhow::anyhow!("{what}: missing `{key}`"))
}

pub(crate) fn pf(j: &Json, key: &str, what: &str) -> anyhow::Result<f64> {
    get(j, key, what)?
        .as_f64_bits()
        .ok_or_else(|| anyhow::anyhow!("{what}: `{key}` is not a bit-exact f64"))
}

pub(crate) fn pu64(j: &Json, key: &str, what: &str) -> anyhow::Result<u64> {
    get(j, key, what)?
        .as_u64_hex()
        .ok_or_else(|| anyhow::anyhow!("{what}: `{key}` is not a hex u64"))
}

pub(crate) fn pusize(j: &Json, key: &str, what: &str) -> anyhow::Result<usize> {
    get(j, key, what)?
        .as_usize()
        .ok_or_else(|| anyhow::anyhow!("{what}: `{key}` is not an integer"))
}

pub(crate) fn pbool(j: &Json, key: &str, what: &str) -> anyhow::Result<bool> {
    get(j, key, what)?
        .as_bool()
        .ok_or_else(|| anyhow::anyhow!("{what}: `{key}` is not a boolean"))
}

pub(crate) fn pstr<'j>(j: &'j Json, key: &str, what: &str) -> anyhow::Result<&'j str> {
    get(j, key, what)?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("{what}: `{key}` is not a string"))
}

pub(crate) fn parr<'j>(j: &'j Json, key: &str, what: &str) -> anyhow::Result<&'j [Json]> {
    get(j, key, what)?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{what}: `{key}` is not an array"))
}

// ----------------------------------------------------------- requests

pub(crate) fn request_to_json(r: &Request) -> Json {
    Json::obj()
        .set("id", Json::u64_hex(r.id))
        .set("arrival", Json::f64_bits(r.arrival))
        .set("input", r.input_tokens)
        .set("output", r.output_tokens)
        .set("retries", r.retries as usize)
        .set(
            "session",
            match r.session {
                None => Json::Null,
                Some(s) => Json::obj()
                    .set("id", Json::u64_hex(s.id))
                    .set("prefix", s.prefix_tokens),
            },
        )
}

pub(crate) fn request_from_json(j: &Json) -> anyhow::Result<Request> {
    let session = match get(j, "session", "request")? {
        Json::Null => None,
        s => Some(crate::workload::SessionRef {
            id: pu64(s, "id", "request-session")?,
            prefix_tokens: pusize(s, "prefix", "request-session")?,
        }),
    };
    Ok(Request {
        id: pu64(j, "id", "request")?,
        arrival: pf(j, "arrival", "request")?,
        input_tokens: pusize(j, "input", "request")?,
        output_tokens: pusize(j, "output", "request")?,
        retries: pusize(j, "retries", "request")? as u32,
        session,
    })
}

pub(crate) fn opt_request_to_json(r: &Option<Request>) -> Json {
    match r {
        None => Json::Null,
        Some(r) => request_to_json(r),
    }
}

pub(crate) fn opt_request_from_json(j: &Json) -> anyhow::Result<Option<Request>> {
    match j {
        Json::Null => Ok(None),
        other => Ok(Some(request_from_json(other)?)),
    }
}

// ------------------------------------------------------ ids and labels

pub(crate) fn iid_to_json(id: InstanceId) -> Json {
    Json::obj()
        .set("slot", id.slot())
        .set("seq", Json::u64_hex(id.seq()))
}

pub(crate) fn iid_from_json(j: &Json) -> anyhow::Result<InstanceId> {
    Ok(InstanceId::new(
        pusize(j, "slot", "instance-id")? as u32,
        pu64(j, "seq", "instance-id")?,
    ))
}

fn role_label(role: Role) -> &'static str {
    match role {
        Role::Prefiller => "prefiller",
        Role::Decoder => "decoder",
        Role::ConvertibleDecoder => "convertible",
    }
}

fn role_from_label(s: &str) -> anyhow::Result<Role> {
    Ok(match s {
        "prefiller" => Role::Prefiller,
        "decoder" => Role::Decoder,
        "convertible" => Role::ConvertibleDecoder,
        other => anyhow::bail!("unknown role label `{other}`"),
    })
}

fn life_label(life: LifeState) -> &'static str {
    match life {
        LifeState::Starting => "starting",
        LifeState::Running => "running",
        LifeState::Draining => "draining",
    }
}

fn life_from_label(s: &str) -> anyhow::Result<LifeState> {
    Ok(match s {
        "starting" => LifeState::Starting,
        "running" => LifeState::Running,
        "draining" => LifeState::Draining,
        other => anyhow::bail!("unknown life-state label `{other}`"),
    })
}

// -------------------------------------------------------------- events

pub(crate) fn event_to_json(ev: &Event) -> Json {
    match ev {
        Event::Arrival => Json::obj().set("kind", "arrival"),
        Event::ControlTick => Json::obj().set("kind", "control-tick"),
        Event::SampleTick => Json::obj().set("kind", "sample-tick"),
        Event::ObsTick => Json::obj().set("kind", "obs-tick"),
        Event::InstanceReady { instance } => Json::obj()
            .set("kind", "instance-ready")
            .set("instance", iid_to_json(*instance)),
        Event::PrefillDone { instance, req } => Json::obj()
            .set("kind", "prefill-done")
            .set("instance", iid_to_json(*instance))
            .set("req", Json::u64_hex(*req)),
        Event::TransferDone { instance, req } => Json::obj()
            .set("kind", "transfer-done")
            .set("instance", iid_to_json(*instance))
            .set("req", Json::u64_hex(*req)),
        Event::DecodeIterDone { instance, epoch } => Json::obj()
            .set("kind", "decode-iter-done")
            .set("instance", iid_to_json(*instance))
            .set("epoch", Json::u64_hex(*epoch)),
        Event::Fault { firing } => Json::obj().set("kind", "fault").set("firing", *firing),
        Event::FaultKill { instance } => Json::obj()
            .set("kind", "fault-kill")
            .set("instance", iid_to_json(*instance)),
        Event::FaultRestore { instance } => Json::obj()
            .set("kind", "fault-restore")
            .set("instance", iid_to_json(*instance)),
    }
}

pub(crate) fn event_from_json(j: &Json) -> anyhow::Result<Event> {
    let kind = pstr(j, "kind", "event")?;
    let iid = |j: &Json| iid_from_json(get(j, "instance", "event")?);
    Ok(match kind {
        "arrival" => Event::Arrival,
        "control-tick" => Event::ControlTick,
        "sample-tick" => Event::SampleTick,
        "obs-tick" => Event::ObsTick,
        "instance-ready" => Event::InstanceReady { instance: iid(j)? },
        "prefill-done" => Event::PrefillDone {
            instance: iid(j)?,
            req: pu64(j, "req", "event")?,
        },
        "transfer-done" => Event::TransferDone {
            instance: iid(j)?,
            req: pu64(j, "req", "event")?,
        },
        "decode-iter-done" => Event::DecodeIterDone {
            instance: iid(j)?,
            epoch: pu64(j, "epoch", "event")?,
        },
        "fault" => Event::Fault {
            firing: pusize(j, "firing", "event")?,
        },
        "fault-kill" => Event::FaultKill { instance: iid(j)? },
        "fault-restore" => Event::FaultRestore { instance: iid(j)? },
        other => anyhow::bail!("unknown event kind `{other}`"),
    })
}

// ------------------------------------------------- sequences and jobs

pub(crate) fn seq_to_json(s: &ActiveSeq) -> Json {
    Json::obj()
        .set("req", request_to_json(&s.req))
        .set("generated", s.generated)
        .set("ctx", s.ctx)
        .set(
            "first_token_at",
            match s.first_token_at {
                None => Json::Null,
                Some(t) => Json::f64_bits(t),
            },
        )
        .set("bucket", s.predicted_bucket)
}

pub(crate) fn seq_from_json(j: &Json) -> anyhow::Result<ActiveSeq> {
    let first_token_at = match get(j, "first_token_at", "active-seq")? {
        Json::Null => None,
        other => Some(
            other
                .as_f64_bits()
                .ok_or_else(|| anyhow::anyhow!("active-seq: bad `first_token_at`"))?,
        ),
    };
    Ok(ActiveSeq {
        req: request_from_json(get(j, "req", "active-seq")?)?,
        generated: pusize(j, "generated", "active-seq")?,
        ctx: pusize(j, "ctx", "active-seq")?,
        first_token_at,
        predicted_bucket: pusize(j, "bucket", "active-seq")?,
    })
}

pub(crate) fn job_to_json(job: &PrefillJob) -> Json {
    Json::obj()
        .set("req", request_to_json(&job.req))
        .set("remaining", job.remaining)
        .set("cached", job.cached)
        .set("enqueued_at", Json::f64_bits(job.enqueued_at))
        .set(
            "chunk_override",
            match job.chunk_override {
                None => Json::Null,
                Some(c) => Json::from(c),
            },
        )
}

pub(crate) fn job_from_json(j: &Json) -> anyhow::Result<PrefillJob> {
    let chunk_override = match get(j, "chunk_override", "prefill-job")? {
        Json::Null => None,
        other => Some(
            other
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("prefill-job: bad `chunk_override`"))?,
        ),
    };
    Ok(PrefillJob {
        req: request_from_json(get(j, "req", "prefill-job")?)?,
        remaining: pusize(j, "remaining", "prefill-job")?,
        cached: pusize(j, "cached", "prefill-job")?,
        enqueued_at: pf(j, "enqueued_at", "prefill-job")?,
        chunk_override,
    })
}

// ------------------------------------------------------------ instances

pub(crate) fn instance_to_json(i: &Instance) -> Json {
    Json::obj()
        .set("id", iid_to_json(i.id))
        .set("role", role_label(i.role))
        .set("life", life_label(i.life))
        .set("ready_at", Json::f64_bits(i.ready_at))
        .set("spawned_at", Json::f64_bits(i.spawned_at))
        .set(
            "prefill_queue",
            Json::Arr(i.prefill_queue.iter().map(job_to_json).collect()),
        )
        .set(
            "active_prefill",
            match &i.active_prefill {
                None => Json::Null,
                Some(job) => job_to_json(job),
            },
        )
        .set("prefill_done_at", Json::f64_bits(i.prefill_done_at))
        .set("batch", Json::Arr(i.batch.iter().map(seq_to_json).collect()))
        .set("joining", Json::Arr(i.joining.iter().map(seq_to_json).collect()))
        .set("reserved_tokens", Json::f64_bits(i.reserved_tokens))
        .set("iter_epoch", Json::u64_hex(i.iter_epoch))
        .set("iterating", i.iterating)
        .set("iter_chunk", i.iter_chunk)
        .set("chunk_size", i.chunk_size)
        .set(
            "convertible_reserve_tokens",
            Json::f64_bits(i.convertible_reserve_tokens),
        )
        .set("win_active", i.win_active)
        .set("win_total", i.win_total as usize)
        .set("win_done", i.win_done as usize)
        .set("win_t", Json::f64_bits(i.win_t))
        .set("win_t1", Json::f64_bits(i.win_t1))
        .set("win_sum_ctx0", Json::u64_hex(i.win_sum_ctx0))
        .set("perf_factor", Json::f64_bits(i.perf_factor))
        .set("degrade_until", Json::f64_bits(i.degrade_until))
        .set("kvcache", i.kvcache.to_json())
}

pub(crate) fn instance_from_json(
    j: &Json,
    engine: Arc<EngineModel>,
) -> anyhow::Result<Instance> {
    let what = "instance";
    let mut inst = Instance::new(
        iid_from_json(get(j, "id", what)?)?,
        role_from_label(pstr(j, "role", what)?)?,
        engine,
        0.0,
        0.0,
    );
    inst.life = life_from_label(pstr(j, "life", what)?)?;
    inst.ready_at = pf(j, "ready_at", what)?;
    inst.spawned_at = pf(j, "spawned_at", what)?;
    inst.prefill_queue = parr(j, "prefill_queue", what)?
        .iter()
        .map(job_from_json)
        .collect::<anyhow::Result<_>>()?;
    inst.active_prefill = match get(j, "active_prefill", what)? {
        Json::Null => None,
        other => Some(job_from_json(other)?),
    };
    inst.prefill_done_at = pf(j, "prefill_done_at", what)?;
    inst.batch = parr(j, "batch", what)?
        .iter()
        .map(seq_from_json)
        .collect::<anyhow::Result<_>>()?;
    inst.joining = parr(j, "joining", what)?
        .iter()
        .map(seq_from_json)
        .collect::<anyhow::Result<_>>()?;
    inst.reserved_tokens = pf(j, "reserved_tokens", what)?;
    inst.iter_epoch = pu64(j, "iter_epoch", what)?;
    inst.iterating = pbool(j, "iterating", what)?;
    inst.iter_chunk = pusize(j, "iter_chunk", what)?;
    inst.chunk_size = pusize(j, "chunk_size", what)?;
    inst.convertible_reserve_tokens = pf(j, "convertible_reserve_tokens", what)?;
    inst.win_active = pbool(j, "win_active", what)?;
    inst.win_total = pusize(j, "win_total", what)? as u32;
    inst.win_done = pusize(j, "win_done", what)? as u32;
    inst.win_t = pf(j, "win_t", what)?;
    inst.win_t1 = pf(j, "win_t1", what)?;
    inst.win_sum_ctx0 = pu64(j, "win_sum_ctx0", what)?;
    inst.perf_factor = pf(j, "perf_factor", what)?;
    inst.degrade_until = pf(j, "degrade_until", what)?;
    inst.kvcache = super::kvcache::PrefixCache::from_json(get(j, "kvcache", what)?)?;
    Ok(inst)
}

// ------------------------------------------------------ decision audit

fn reject_from_label(s: &str) -> anyhow::Result<RejectReason> {
    RejectReason::ALL
        .iter()
        .copied()
        .find(|r| r.label() == s)
        .ok_or_else(|| anyhow::anyhow!("unknown reject reason `{s}`"))
}

fn signal_kind_from_label(s: &str) -> anyhow::Result<SignalKind> {
    const ALL: [SignalKind; 8] = [
        SignalKind::Arrival,
        SignalKind::RetryPrefill,
        SignalKind::PrefillDone,
        SignalKind::Completion,
        SignalKind::Tick,
        SignalKind::InstanceReady,
        SignalKind::InstanceDrained,
        SignalKind::InstanceFailed,
    ];
    ALL.iter()
        .copied()
        .find(|k| k.label() == s)
        .ok_or_else(|| anyhow::anyhow!("unknown signal kind `{s}`"))
}

fn action_to_json(a: &Action) -> Json {
    match a {
        Action::RoutePrefill { req, target } => Json::obj()
            .set("kind", "route-prefill")
            .set("req", Json::u64_hex(*req))
            .set("target", iid_to_json(*target)),
        Action::DeflectPrefill { req, decoder, chunked } => Json::obj()
            .set("kind", "deflect-prefill")
            .set("req", Json::u64_hex(*req))
            .set("decoder", iid_to_json(*decoder))
            .set("chunked", *chunked),
        Action::DispatchDecode { req, decoder, bucket } => Json::obj()
            .set("kind", "dispatch-decode")
            .set("req", Json::u64_hex(*req))
            .set("decoder", iid_to_json(*decoder))
            .set("bucket", *bucket),
        Action::SetFleet { role, target } => Json::obj()
            .set("kind", "set-fleet")
            .set("role", role_label(*role))
            .set("target", *target),
        Action::Convert { decoder } => Json::obj()
            .set("kind", "convert")
            .set("decoder", iid_to_json(*decoder)),
        Action::Revert { decoder } => Json::obj()
            .set("kind", "revert")
            .set("decoder", iid_to_json(*decoder)),
        Action::Drain { instance } => Json::obj()
            .set("kind", "drain")
            .set("instance", iid_to_json(*instance)),
        Action::Fault { instance, kind } => Json::obj()
            .set("kind", "fault")
            .set("instance", iid_to_json(*instance))
            .set("fault", kind.label()),
    }
}

fn action_from_json(j: &Json) -> anyhow::Result<Action> {
    let what = "action";
    Ok(match pstr(j, "kind", what)? {
        "route-prefill" => Action::RoutePrefill {
            req: pu64(j, "req", what)?,
            target: iid_from_json(get(j, "target", what)?)?,
        },
        "deflect-prefill" => Action::DeflectPrefill {
            req: pu64(j, "req", what)?,
            decoder: iid_from_json(get(j, "decoder", what)?)?,
            chunked: pbool(j, "chunked", what)?,
        },
        "dispatch-decode" => Action::DispatchDecode {
            req: pu64(j, "req", what)?,
            decoder: iid_from_json(get(j, "decoder", what)?)?,
            bucket: pusize(j, "bucket", what)?,
        },
        "set-fleet" => Action::SetFleet {
            role: role_from_label(pstr(j, "role", what)?)?,
            target: pusize(j, "target", what)?,
        },
        "convert" => Action::Convert {
            decoder: iid_from_json(get(j, "decoder", what)?)?,
        },
        "revert" => Action::Revert {
            decoder: iid_from_json(get(j, "decoder", what)?)?,
        },
        "drain" => Action::Drain {
            instance: iid_from_json(get(j, "instance", what)?)?,
        },
        "fault" => Action::Fault {
            instance: iid_from_json(get(j, "instance", what)?)?,
            kind: FaultLabel::from_label(pstr(j, "fault", what)?)
                .ok_or_else(|| anyhow::anyhow!("unknown fault label"))?,
        },
        other => anyhow::bail!("unknown action kind `{other}`"),
    })
}

fn outcome_to_json(o: &ActionOutcome) -> Json {
    match o {
        ActionOutcome::Applied => Json::obj().set("status", "applied"),
        ActionOutcome::Clamped(r) => Json::obj().set("status", "clamped").set("reason", r.label()),
        ActionOutcome::Rejected(r) => {
            Json::obj().set("status", "rejected").set("reason", r.label())
        }
    }
}

fn outcome_from_json(j: &Json) -> anyhow::Result<ActionOutcome> {
    Ok(match pstr(j, "status", "outcome")? {
        "applied" => ActionOutcome::Applied,
        "clamped" => ActionOutcome::Clamped(reject_from_label(pstr(j, "reason", "outcome")?)?),
        "rejected" => ActionOutcome::Rejected(reject_from_label(pstr(j, "reason", "outcome")?)?),
        other => anyhow::bail!("unknown outcome status `{other}`"),
    })
}

/// Lossless decision-log serialization (distinct from the human-facing
/// `DecisionLog::to_json` export, which flattens actions into labels).
pub(crate) fn decision_log_to_json(log: &DecisionLog) -> Json {
    Json::obj()
        .set("capacity", log.capacity())
        .set("total_seen", Json::u64_hex(log.total_seen()))
        .set(
            "records",
            Json::Arr(
                log.iter()
                    .map(|r| {
                        Json::obj()
                            .set("t", Json::f64_bits(r.t))
                            .set("signal", r.signal.label())
                            .set("action", action_to_json(&r.action))
                            .set("outcome", outcome_to_json(&r.outcome))
                            .set(
                                "sample",
                                match r.sample {
                                    None => Json::Null,
                                    Some(s) => Json::from(s as usize),
                                },
                            )
                    })
                    .collect(),
            ),
        )
}

pub(crate) fn decision_log_from_json(j: &Json) -> anyhow::Result<DecisionLog> {
    let what = "decision-log";
    let mut records = Vec::new();
    for r in parr(j, "records", what)? {
        let sample = match get(r, "sample", what)? {
            Json::Null => None,
            other => Some(
                other
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("{what}: bad `sample`"))? as u32,
            ),
        };
        records.push(DecisionRecord {
            t: pf(r, "t", what)?,
            signal: signal_kind_from_label(pstr(r, "signal", what)?)?,
            action: action_from_json(get(r, "action", what)?)?,
            outcome: outcome_from_json(get(r, "outcome", what)?)?,
            sample,
        });
    }
    Ok(DecisionLog::from_parts(
        pusize(j, "capacity", what)?,
        pu64(j, "total_seen", what)?,
        records,
    ))
}

// --------------------------------------------------------- time series

pub(crate) fn series_to_json(s: &crate::metrics::TimeSeries) -> Json {
    Json::obj().set("name", s.name.as_str()).set(
        "points",
        Json::Arr(
            s.points
                .iter()
                .map(|(t, v)| Json::Arr(vec![Json::f64_bits(*t), Json::f64_bits(*v)]))
                .collect(),
        ),
    )
}

pub(crate) fn series_from_json(j: &Json) -> anyhow::Result<crate::metrics::TimeSeries> {
    let mut s = crate::metrics::TimeSeries::new(pstr(j, "name", "time-series")?);
    for (i, p) in parr(j, "points", "time-series")?.iter().enumerate() {
        let pair = p
            .as_arr()
            .filter(|p| p.len() == 2)
            .ok_or_else(|| anyhow::anyhow!("time-series: point {i} is not a pair"))?;
        let t = pair[0]
            .as_f64_bits()
            .ok_or_else(|| anyhow::anyhow!("time-series: bad point time"))?;
        let v = pair[1]
            .as_f64_bits()
            .ok_or_else(|| anyhow::anyhow!("time-series: bad point value"))?;
        s.points.push((t, v));
    }
    Ok(s)
}

/// `(time, value)` pair lists (ttft points, wait clocks).
pub(crate) fn pairs_to_json(pairs: &[(f64, f64)]) -> Json {
    Json::Arr(
        pairs
            .iter()
            .map(|(a, b)| Json::Arr(vec![Json::f64_bits(*a), Json::f64_bits(*b)]))
            .collect(),
    )
}

pub(crate) fn pairs_from_json(j: &Json, what: &str) -> anyhow::Result<Vec<(f64, f64)>> {
    let arr = j
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{what}: expected an array of pairs"))?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, p) in arr.iter().enumerate() {
        let pair = p
            .as_arr()
            .filter(|p| p.len() == 2)
            .ok_or_else(|| anyhow::anyhow!("{what}: entry {i} is not a pair"))?;
        let a = pair[0]
            .as_f64_bits()
            .ok_or_else(|| anyhow::anyhow!("{what}: bad pair value"))?;
        let b = pair[1]
            .as_f64_bits()
            .ok_or_else(|| anyhow::anyhow!("{what}: bad pair value"))?;
        out.push((a, b));
    }
    Ok(out)
}

// --------------------------------------------------------- policy state

/// Serialized control-plane internals, captured through the
/// `ControlPlane::save_state`/`restore_state` hook. Stateless policies
/// carry `Json::Null`; stateful ones serialize their traffic windows,
/// hysteresis streaks and RNG stream positions bit-exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct PolicyState {
    /// The policy's `ControlPlane::name()` — restore refuses a mismatch.
    pub policy: String,
    pub data: Json,
}

impl PolicyState {
    pub fn new(policy: impl Into<String>, data: Json) -> PolicyState {
        PolicyState {
            policy: policy.into(),
            data,
        }
    }

    /// State of a policy with nothing to save.
    pub fn stateless(policy: impl Into<String>) -> PolicyState {
        PolicyState::new(policy, Json::Null)
    }

    /// Guard a restore against state saved by a different policy.
    pub fn expect(&self, policy: &str) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.policy == policy,
            "policy state was saved by `{}`, cannot restore into `{policy}`",
            self.policy
        );
        Ok(())
    }

    /// Fetch a required sub-object of `data`.
    pub fn part<'j>(&'j self, key: &str) -> anyhow::Result<&'j Json> {
        get(&self.data, key, "policy state")
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("policy", self.policy.as_str())
            .set("data", self.data.clone())
    }

    pub fn from_json(j: &Json) -> anyhow::Result<PolicyState> {
        Ok(PolicyState {
            policy: pstr(j, "policy", "policy state")?.to_string(),
            data: get(j, "data", "policy state")?.clone(),
        })
    }
}

// ------------------------------------------------------------ snapshot

/// A complete, serializable capture of a mid-run simulation. Produced by
/// `SimEngine::checkpoint`, consumed by `SimEngine::resume`; survives a
/// JSON text round trip losslessly (`save`/`load`).
#[derive(Clone, Debug, PartialEq)]
pub struct SimSnapshot {
    pub version: u64,
    /// Arrival-source label at capture time (provenance only).
    pub label: String,
    /// Simulated time of the last processed event.
    pub t: f64,
    /// Arrivals pulled from the source so far — the stream resume
    /// position (`trace::fast_forward` skips this many on resume).
    pub arrivals_pulled: u64,
    /// Control-plane internals via the `ControlPlane` snapshot hook.
    pub policy: PolicyState,
    /// Engine + cluster + metrics state blob (see engine.rs `checkpoint`).
    pub engine: Json,
}

impl SimSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("schema_version", self.version)
            .set("label", self.label.as_str())
            .set("t", Json::f64_bits(self.t))
            .set("arrivals_pulled", Json::u64_hex(self.arrivals_pulled))
            .set("policy", self.policy.to_json())
            .set("engine", self.engine.clone())
    }

    pub fn from_json(j: &Json) -> anyhow::Result<SimSnapshot> {
        let version = j
            .get("schema_version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("snapshot: missing `schema_version`"))?
            as u64;
        anyhow::ensure!(
            version == SNAPSHOT_SCHEMA_VERSION,
            "snapshot schema v{version} is not supported (this build reads v{SNAPSHOT_SCHEMA_VERSION})"
        );
        Ok(SimSnapshot {
            version,
            label: pstr(j, "label", "snapshot")?.to_string(),
            t: pf(j, "t", "snapshot")?,
            arrivals_pulled: pu64(j, "arrivals_pulled", "snapshot")?,
            policy: PolicyState::from_json(get(j, "policy", "snapshot")?)?,
            engine: get(j, "engine", "snapshot")?.clone(),
        })
    }

    /// Write the snapshot (pretty-printed JSON) to `path`.
    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().pretty())
            .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", path.display()))
    }

    /// Read a snapshot written by [`SimSnapshot::save`].
    pub fn load(path: &std::path::Path) -> anyhow::Result<SimSnapshot> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
        SimSnapshot::from_json(&Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_and_event_codecs_round_trip() {
        let r = Request::new(u64::MAX - 3, 1234.5678e-3, 8192, 1);
        let back = request_from_json(&request_to_json(&r)).unwrap();
        assert_eq!(back, r);
        assert_eq!(back.arrival.to_bits(), r.arrival.to_bits());

        let id = InstanceId::new(7, 0xFFFF_FFFF_FFFF_FF01);
        for ev in [
            Event::Arrival,
            Event::ControlTick,
            Event::SampleTick,
            Event::ObsTick,
            Event::InstanceReady { instance: id },
            Event::PrefillDone { instance: id, req: 42 },
            Event::TransferDone { instance: id, req: 43 },
            Event::DecodeIterDone { instance: id, epoch: u64::MAX },
            Event::Fault { firing: 5 },
            Event::FaultKill { instance: id },
            Event::FaultRestore { instance: id },
        ] {
            let back = event_from_json(&event_to_json(&ev)).unwrap();
            assert_eq!(back, ev);
        }
    }

    #[test]
    fn action_codec_round_trips_every_variant() {
        let id = InstanceId::new(3, 11);
        let actions = [
            Action::RoutePrefill { req: 1, target: id },
            Action::DeflectPrefill { req: 2, decoder: id, chunked: true },
            Action::DispatchDecode { req: 3, decoder: id, bucket: 8 },
            Action::SetFleet { role: Role::ConvertibleDecoder, target: 4 },
            Action::Convert { decoder: id },
            Action::Revert { decoder: id },
            Action::Drain { instance: id },
            Action::Fault { instance: id, kind: FaultLabel::PreemptKill },
        ];
        for a in actions {
            assert_eq!(action_from_json(&action_to_json(&a)).unwrap(), a);
        }
        for o in [
            ActionOutcome::Applied,
            ActionOutcome::Clamped(RejectReason::FleetOverQuota),
            ActionOutcome::Rejected(RejectReason::Busy),
        ] {
            assert_eq!(outcome_from_json(&outcome_to_json(&o)).unwrap(), o);
        }
    }

    #[test]
    fn decision_log_codec_round_trips_through_text() {
        let mut log = DecisionLog::new(4);
        for k in 0..6u64 {
            log.push(DecisionRecord {
                t: k as f64 * 0.25,
                signal: SignalKind::Tick,
                action: Action::SetFleet { role: Role::Prefiller, target: k as usize },
                outcome: if k % 2 == 0 {
                    ActionOutcome::Applied
                } else {
                    ActionOutcome::Rejected(RejectReason::NotRunning)
                },
                sample: if k % 3 == 0 { None } else { Some(k as u32) },
            });
        }
        let text = decision_log_to_json(&log).pretty();
        let back = decision_log_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.capacity(), 4);
        assert_eq!(back.total_seen(), 6);
        assert_eq!(back.len(), log.len());
        for (a, b) in back.iter().zip(log.iter()) {
            assert_eq!(a.t.to_bits(), b.t.to_bits());
            assert_eq!(a.action, b.action);
            assert_eq!(a.outcome, b.outcome);
            assert_eq!(a.signal, b.signal);
            assert_eq!(a.sample, b.sample);
        }
    }

    #[test]
    fn snapshot_wrapper_round_trips_and_gates_version() {
        let snap = SimSnapshot {
            version: SNAPSHOT_SCHEMA_VERSION,
            label: "demo".into(),
            t: 12.75,
            arrivals_pulled: 1 << 60,
            policy: PolicyState::new("tokenscale", Json::obj().set("x", 1.0)),
            engine: Json::obj().set("now", Json::f64_bits(12.75)),
        };
        let text = snap.to_json().pretty();
        let back = SimSnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, snap);

        let future = snap.to_json().set("schema_version", 999usize);
        assert!(SimSnapshot::from_json(&future).is_err());
        assert!(PolicyState::new("a", Json::Null).expect("b").is_err());
    }
}
