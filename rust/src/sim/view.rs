//! Read-only cluster facade handed to control planes.
//!
//! Policies used to receive `&Cluster` directly, which exposed the slab
//! internals and every `&mut` entry point. [`ClusterView`] is the v2
//! contract: a `Copy` wrapper that re-exports only the observational
//! queries. Guarantees:
//!
//! - **Snapshot consistency** — the view is taken at signal-dispatch time;
//!   nothing mutates the cluster while a policy holds it (dispatch is
//!   synchronous), so every query in one `on_signal` call sees the same
//!   state the engine will validate the returned actions against.
//! - **No mutation** — there is no way to reach `&mut Instance` or the
//!   lifecycle entry points; all cluster changes go through typed
//!   [`Action`](super::policy::Action)s the engine validates.
//! - **Stable iteration order** — instances iterate in spawn order within
//!   a role (the slab's per-role live lists), so min-by tie-breaks are
//!   deterministic and favor the oldest instance.

use super::cluster::{Cluster, ClusterConfig, FailureRecord};
use super::event::InstanceId;
use super::instance::{Instance, Role};

/// Read-only view of the live cluster.
#[derive(Clone, Copy)]
pub struct ClusterView<'a> {
    cluster: &'a Cluster,
}

impl<'a> ClusterView<'a> {
    pub fn new(cluster: &'a Cluster) -> ClusterView<'a> {
        ClusterView { cluster }
    }

    /// Deployment-level configuration (engines, GPU cap, chunk budgets).
    pub fn config(&self) -> &'a ClusterConfig {
        &self.cluster.config
    }

    /// Hard cap on simultaneously allocated GPUs.
    pub fn max_gpus(&self) -> usize {
        self.cluster.config.max_gpus
    }

    /// GPUs currently allocated (including Starting and Draining).
    pub fn allocated_gpus(&self) -> usize {
        self.cluster.allocated_gpus()
    }

    /// GPUs held by live instances of one role.
    pub fn role_gpus(&self, role: Role) -> usize {
        self.cluster.role_gpus(role)
    }

    /// Live instances of one role (any life state).
    pub fn count_role(&self, role: Role) -> usize {
        self.cluster.count_role(role)
    }

    /// Non-draining instances of one role (the autoscalers' "current
    /// count").
    pub fn active_count(&self, role: Role) -> usize {
        self.cluster.active_count(role)
    }

    /// Look up one instance by id (`None` for stale ids).
    pub fn get(&self, id: InstanceId) -> Option<&'a Instance> {
        self.cluster.get(id)
    }

    /// Iterate all live instances, prefillers → decoders → convertibles,
    /// spawn order within each role.
    pub fn iter(&self) -> impl Iterator<Item = &'a Instance> + 'a {
        self.cluster.iter()
    }

    /// Iterate live instances of one role (any life state), spawn order.
    pub fn iter_role(&self, role: Role) -> impl Iterator<Item = &'a Instance> + 'a {
        self.cluster.iter_role(role)
    }

    /// Iterate running instances of one role, spawn order.
    pub fn running_of(&self, role: Role) -> impl Iterator<Item = &'a Instance> + 'a {
        self.cluster.running_of(role)
    }

    /// Ids of non-draining instances of a role, spawn order.
    pub fn ids_of(&self, role: Role) -> Vec<InstanceId> {
        self.cluster.ids_of(role)
    }

    /// Injected-fault ledger (crashes, preemptions, degradations),
    /// oldest first. Empty unless a `FaultPlan` is armed — policies can
    /// use it to distinguish failure-induced backpressure from load.
    pub fn failures(&self) -> &'a [FailureRecord] {
        &self.cluster.failures
    }

    /// Iterate running instances currently inside a degradation window
    /// (stragglers), spawn order across all roles.
    pub fn degraded(&self) -> impl Iterator<Item = &'a Instance> + 'a {
        self.cluster.iter().filter(|i| i.is_degraded())
    }

    // ---- prefix-cache observation (`sim::kvcache`) ----
    //
    // Cache-aware routers score placement by warm overlap; these queries
    // are read-only (no LRU touch, no hit/miss counter movement), so a
    // policy probing every candidate does not perturb cache state.

    /// Warm prefix tokens instance `id` could reuse for `req` (0 for
    /// stale ids, sessionless requests, or disabled caches).
    pub fn warm_overlap(&self, id: InstanceId, req: &crate::workload::Request) -> usize {
        self.cluster.get(id).map_or(0, |i| i.warm_overlap(req))
    }

    /// Occupied fraction of instance `id`'s prefix-cache block pool
    /// (0.0 for stale ids or disabled caches).
    pub fn cache_occupancy(&self, id: InstanceId) -> f64 {
        self.cluster.get(id).map_or(0.0, |i| i.kvcache.occupancy())
    }

    /// Aggregate (lookup hits, misses, evictions) across all live
    /// instances' prefix caches.
    pub fn cache_counters(&self) -> (u64, u64, u64) {
        self.cluster.iter().fold((0, 0, 0), |acc, i| {
            (
                acc.0 + i.kvcache.hits,
                acc.1 + i.kvcache.misses,
                acc.2 + i.kvcache.evictions,
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::{catalog, EngineModel};
    use std::sync::Arc;

    fn cluster() -> Cluster {
        let engine = Arc::new(EngineModel::new(
            catalog::model("llama-3.1-8b").unwrap(),
            catalog::gpu("a100-40g").unwrap(),
            1,
        ));
        Cluster::new(ClusterConfig {
            prefill_engine: engine.clone(),
            decode_engine: engine,
            startup_override_s: None,
            max_gpus: 8,
            convertible_chunk_size: 512,
            convertible_reserve_tokens: 4096.0,
            kvcache: crate::sim::KvCacheConfig::disabled(),
        })
    }

    #[test]
    fn view_mirrors_cluster_queries() {
        let mut c = cluster();
        let p = c.spawn(Role::Prefiller, 0.0, Some(0.0)).unwrap();
        c.spawn(Role::Decoder, 0.0, Some(0.0)).unwrap();
        let v = ClusterView::new(&c);
        assert_eq!(v.allocated_gpus(), c.allocated_gpus());
        assert_eq!(v.active_count(Role::Prefiller), 1);
        assert_eq!(v.running_of(Role::Decoder).count(), 1);
        assert_eq!(v.get(p).unwrap().id, p);
        assert_eq!(v.max_gpus(), 8);
        assert_eq!(v.ids_of(Role::Prefiller), vec![p]);
        assert_eq!(v.iter().count(), 2);
    }

    #[test]
    fn cache_queries_are_read_only() {
        use crate::sim::KvCacheConfig;
        use crate::workload::Request;
        let mut c = cluster();
        c.config.kvcache = KvCacheConfig {
            capacity_tokens: 4096,
            block_tokens: 16,
        };
        let p = c.spawn(Role::Prefiller, 0.0, Some(0.0)).unwrap();
        c.get_mut(p).unwrap().kvcache.insert(9, 600);
        let req = Request::new(0, 1.0, 800, 64).with_session(9, 700);
        let c = c; // freeze
        let v = ClusterView::new(&c);
        assert_eq!(v.warm_overlap(p, &req), 600);
        assert!(v.cache_occupancy(p) > 0.0);
        let before = v.cache_counters();
        // Probing candidates must not move LRU clocks or counters.
        for _ in 0..10 {
            v.warm_overlap(p, &req);
        }
        assert_eq!(v.cache_counters(), before);
        assert_eq!(v.warm_overlap(p, &Request::new(1, 1.0, 100, 10)), 0);
    }
}
