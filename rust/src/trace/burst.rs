//! Burst analytics over traces: the running-average method of the paper's
//! §II-C1, powering Fig. 2 (traffic vs trendline) and Fig. 3
//! (burst fraction vs overprovisioning ratio).

use super::gen::Trace;

/// Per-second binned traffic series for a trace.
#[derive(Clone, Debug)]
pub struct TrafficSeries {
    /// Bin width, seconds.
    pub bin_s: f64,
    /// Requests per bin.
    pub requests: Vec<f64>,
    /// Input tokens per bin.
    pub tokens: Vec<f64>,
}

/// Bin a trace's arrivals into fixed-width bins.
pub fn bin_traffic(trace: &Trace, bin_s: f64) -> TrafficSeries {
    assert!(bin_s > 0.0);
    let n = (trace.duration_s / bin_s).ceil() as usize;
    let mut requests = vec![0.0; n];
    let mut tokens = vec![0.0; n];
    for r in &trace.requests {
        let idx = ((r.arrival / bin_s) as usize).min(n.saturating_sub(1));
        requests[idx] += 1.0;
        tokens[idx] += r.input_tokens as f64;
    }
    TrafficSeries {
        bin_s,
        requests,
        tokens,
    }
}

/// Running average over a sliding window of `window_s` seconds, evaluated
/// at every bin (the paper's 1-minute sliding window).
pub fn running_average(series: &[f64], bin_s: f64, window_s: f64) -> Vec<f64> {
    let w = (window_s / bin_s).round().max(1.0) as usize;
    let mut out = Vec::with_capacity(series.len());
    let mut sum = 0.0;
    for (i, x) in series.iter().enumerate() {
        sum += x;
        if i >= w {
            sum -= series[i - w];
        }
        let denom = (i + 1).min(w) as f64;
        out.push(sum / denom);
    }
    out
}

/// Fraction of traffic (by volume) exceeding `ratio ×` the running average —
/// i.e. the share a system provisioned at `ratio ×` the trend would fail to
/// absorb instantaneously. This is the paper's Fig. 3 metric.
pub fn burst_fraction(series: &[f64], bin_s: f64, window_s: f64, ratio: f64) -> f64 {
    let trend = running_average(series, bin_s, window_s);
    let mut excess = 0.0;
    let mut total = 0.0;
    for (x, t) in series.iter().zip(&trend) {
        total += x;
        let cap = ratio * t;
        if *x > cap {
            excess += x - cap;
        }
    }
    if total <= 0.0 {
        0.0
    } else {
        excess / total
    }
}

/// Fraction of wall-clock bins that are inside a burst (bin value above the
/// running average) — the paper's "47 % of operational time" statistic.
pub fn burst_time_fraction(series: &[f64], bin_s: f64, window_s: f64) -> f64 {
    let trend = running_average(series, bin_s, window_s);
    if series.is_empty() {
        return 0.0;
    }
    let above = series
        .iter()
        .zip(&trend)
        .filter(|(x, t)| **x > **t * 1.0001 && **x > 0.0)
        .count();
    above as f64 / series.len() as f64
}

/// Mean length (seconds) of maximal runs of consecutive above-trend bins —
/// the paper's "each burst lasting 2.3 s on average".
pub fn mean_burst_len_s(series: &[f64], bin_s: f64, window_s: f64) -> f64 {
    let trend = running_average(series, bin_s, window_s);
    let mut lens = Vec::new();
    let mut run = 0usize;
    for (x, t) in series.iter().zip(&trend) {
        if *x > *t * 1.0001 && *x > 0.0 {
            run += 1;
        } else if run > 0 {
            lens.push(run as f64 * bin_s);
            run = 0;
        }
    }
    if run > 0 {
        lens.push(run as f64 * bin_s);
    }
    if lens.is_empty() {
        0.0
    } else {
        lens.iter().sum::<f64>() / lens.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::gen::generate;
    use crate::trace::spec::TraceFamily;
    use crate::workload::Request;

    fn flat_trace(rps: usize, duration: usize) -> Trace {
        let mut requests = Vec::new();
        let mut id = 0;
        for s in 0..duration {
            for k in 0..rps {
                requests.push(Request::new(id, s as f64 + k as f64 / rps as f64, 100, 50));
                id += 1;
            }
        }
        Trace {
            name: "flat".into(),
            duration_s: duration as f64,
            requests,
        }
    }

    #[test]
    fn bin_conserves_counts() {
        let t = flat_trace(5, 30);
        let s = bin_traffic(&t, 1.0);
        assert_eq!(s.requests.iter().sum::<f64>() as usize, t.requests.len());
        assert_eq!(
            s.tokens.iter().sum::<f64>() as usize,
            t.requests.iter().map(|r| r.input_tokens).sum::<usize>()
        );
    }

    #[test]
    fn flat_traffic_has_no_bursts() {
        let t = flat_trace(5, 120);
        let s = bin_traffic(&t, 1.0);
        assert!(burst_fraction(&s.requests, 1.0, 60.0, 1.5) < 1e-9);
        assert!(burst_time_fraction(&s.requests, 1.0, 60.0) < 0.05);
    }

    #[test]
    fn running_average_smooths() {
        let xs = vec![0.0, 0.0, 10.0, 0.0, 0.0, 0.0];
        let avg = running_average(&xs, 1.0, 3.0);
        assert!(avg[2] < 10.0);
        assert!(avg[2] > 0.0);
    }

    #[test]
    fn burst_fraction_decreases_with_ratio() {
        let spec = TraceFamily::BurstGpt2.spec(20.0, 600.0);
        let t = generate(&spec, 3);
        let s = bin_traffic(&t, 1.0);
        let f1 = burst_fraction(&s.requests, 1.0, 60.0, 1.0);
        let f2 = burst_fraction(&s.requests, 1.0, 60.0, 2.0);
        let f4 = burst_fraction(&s.requests, 1.0, 60.0, 4.0);
        assert!(f1 > f2 && f2 > f4, "f1={f1} f2={f2} f4={f4}");
        assert!(f1 > 0.05, "bursty trace should have bursts, f1={f1}");
    }

    #[test]
    fn azure_conv_burst_time_near_half() {
        // The paper: bursts during ~47 % of time, ~2.3 s average length.
        let spec = TraceFamily::AzureConv.spec(22.0, 900.0);
        let t = generate(&spec, 11);
        let s = bin_traffic(&t, 1.0);
        let frac = burst_time_fraction(&s.requests, 1.0, 60.0);
        assert!((0.30..0.60).contains(&frac), "burst time fraction={frac}");
        let len = mean_burst_len_s(&s.requests, 1.0, 60.0);
        assert!((1.0..5.0).contains(&len), "mean burst len={len}");
    }
}
