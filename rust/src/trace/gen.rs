//! Trace generation: lazy, streaming generators that turn a
//! [`TraceSpec`] into a time-ordered request stream ([`SpecSource`],
//! [`MixedSource`]), the materialized [`Trace`] container, and the
//! synthetic step/burst traces used by the paper's microbenchmarks
//! (Figs. 4, 6, 10).
//!
//! `generate(spec, seed)` is now a thin wrapper that drains the streaming
//! generator; `rust/tests/trace_streaming.rs` pins the stream to the
//! byte-identical sequence the pre-streaming eager generator produced.

use super::source::{materialize, ArrivalSource, TraceProfile, TraceSliceSource};
use super::spec::{base_families, SessionModel, TraceFamily, TraceSpec};
use super::transform::Resample;
use crate::util::rng::Pcg64;
use crate::workload::Request;

/// A generated trace: time-sorted requests plus its spec for reporting.
#[derive(Clone, Debug)]
pub struct Trace {
    pub name: String,
    pub duration_s: f64,
    pub requests: Vec<Request>,
}

impl Trace {
    pub fn avg_rps(&self) -> f64 {
        if self.duration_s <= 0.0 {
            return 0.0;
        }
        self.requests.len() as f64 / self.duration_s
    }

    pub fn avg_input_tokens(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().map(|r| r.input_tokens as f64).sum::<f64>()
            / self.requests.len() as f64
    }

    pub fn avg_output_tokens(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().map(|r| r.output_tokens as f64).sum::<f64>()
            / self.requests.len() as f64
    }

    /// Input-token arrival rate averaged over the whole trace (tok/s).
    pub fn avg_input_tps(&self) -> f64 {
        if self.duration_s <= 0.0 {
            return 0.0;
        }
        self.requests.iter().map(|r| r.input_tokens as f64).sum::<f64>() / self.duration_s
    }

    /// Resample to a target average RPS by uniform thinning (the paper's
    /// §V sampling to 22 RPS) or by duplication with jitter when the target
    /// exceeds the source rate.
    ///
    /// Implemented on the streaming [`Resample`] combinator, which fixes
    /// the old duplication path: output arrivals stay time-sorted (the
    /// jittered copies go through a reorder buffer) and ids are
    /// re-sequenced 0..n in emission order, deterministically from a
    /// generator forked off `rng`.
    pub fn resample_to_rps(&self, target_rps: f64, rng: &mut Pcg64) -> Trace {
        if self.avg_rps() <= 0.0 {
            return self.clone();
        }
        let mut rs = Resample::new(TraceSliceSource::new(self), target_rps, rng.fork());
        materialize(&mut rs)
    }
}

fn sample_len(rng: &mut Pcg64, d: &super::spec::LenDist) -> usize {
    (rng.lognormal(d.mu, d.sigma).round() as usize).clamp(d.min, d.max)
}

/// Streaming generator for one [`TraceSpec`]. Deterministic per seed.
///
/// The arrival process is a two-state Markov-modulated Gamma renewal
/// process: stable ↔ burst episodes (Exp-distributed lengths), with the
/// stable/burst rates solved so that the long-run average hits `spec.rps`
/// and the burst occupancy matches `spec.burst.time_fraction`. A slow
/// sinusoid modulates both, giving the trend the paper's running-average
/// plots show. State (three independent rng streams, episode machine,
/// clock) lives on the source, so a multi-hour trace is generated one
/// arrival at a time instead of as a up-front `Vec`.
pub struct SpecSource {
    spec: TraceSpec,
    arrivals_rng: Pcg64,
    len_rng: Pcg64,
    episode_rng: Pcg64,
    r_stable: f64,
    r_burst: f64,
    mean_stable_gap: f64,
    t: f64,
    in_burst: bool,
    phase_end: f64,
    next_id: u64,
    done: bool,
}

impl SpecSource {
    pub fn new(spec: TraceSpec, seed: u64) -> SpecSource {
        let mut rng = Pcg64::new(seed);
        let arrivals_rng = rng.fork();
        let len_rng = rng.fork();
        let mut episode_rng = rng.fork();

        let bf = &spec.burst;
        // Solve stable rate r_s from: f*k*r_s + (1-f)*r_s = rps
        let r_stable = spec.rps / (bf.time_fraction * bf.rate_factor + (1.0 - bf.time_fraction));
        let r_burst = r_stable * bf.rate_factor;
        // Episode dynamics: mean burst length given; mean stable gap from
        // occupancy: f = mean_burst / (mean_burst + mean_stable).
        let mean_stable_gap = if bf.time_fraction > 0.0 {
            bf.mean_len_s * (1.0 - bf.time_fraction) / bf.time_fraction
        } else {
            f64::INFINITY
        };
        let phase_end = if mean_stable_gap.is_finite() {
            episode_rng.exponential(1.0 / mean_stable_gap)
        } else {
            f64::INFINITY
        };

        SpecSource {
            spec,
            arrivals_rng,
            len_rng,
            episode_rng,
            r_stable,
            r_burst,
            mean_stable_gap,
            t: 0.0,
            in_burst: false,
            phase_end,
            next_id: 0,
            done: false,
        }
    }
}

impl ArrivalSource for SpecSource {
    fn next_request(&mut self) -> Option<Request> {
        // One resumption of the eager generator's loop body: either the
        // clock is already past the horizon (stream exhausted), or one
        // more renewal step lands inside it and yields a request.
        if self.done || self.t >= self.spec.duration_s {
            self.done = true;
            return None;
        }
        // Advance episode state machine past `t`.
        while self.t >= self.phase_end {
            self.in_burst = !self.in_burst;
            let mean = if self.in_burst {
                self.spec.burst.mean_len_s
            } else {
                self.mean_stable_gap
            };
            self.phase_end += self.episode_rng.exponential(1.0 / mean);
        }
        let diurnal = 1.0
            + self.spec.diurnal_amplitude
                * (2.0 * std::f64::consts::PI * self.t / self.spec.diurnal_period_s).sin();
        let rate = (if self.in_burst { self.r_burst } else { self.r_stable }) * diurnal.max(0.05);
        // Gamma renewal with shape k and mean 1/rate → scale = 1/(k*rate).
        let k = self.spec.arrival_shape;
        let gap = self.arrivals_rng.gamma(k, 1.0 / (k * rate));
        self.t += gap;
        if self.t >= self.spec.duration_s {
            self.done = true;
            return None;
        }
        let input = sample_len(&mut self.len_rng, &self.spec.input_len);
        let output = sample_len(&mut self.len_rng, &self.spec.output_len);
        let req = Request::new(self.next_id, self.t, input, output);
        self.next_id += 1;
        Some(req)
    }

    fn duration_s(&self) -> f64 {
        self.spec.duration_s
    }

    fn label(&self) -> String {
        self.spec.name.clone()
    }

    fn profile(&self) -> TraceProfile {
        TraceProfile {
            avg_rps: self.spec.rps,
            avg_input_tokens: self.spec.input_len.mean(),
            avg_output_tokens: self.spec.output_len.mean(),
            duration_s: self.spec.duration_s,
        }
    }
}

/// Streaming Mixed workload: Azure Conversation + Azure Code +
/// BurstGPT 1/2 interleaved at equal request rates (§V Workload
/// Generation) via a 4-way time-ordered merge, ids re-sequenced at
/// emission. Ties break toward the lower family index, matching the
/// stable sort of the eager implementation.
pub struct MixedSource {
    subs: Vec<SpecSource>,
    peeked: Vec<Option<Request>>,
    total_rps: f64,
    duration_s: f64,
    next_id: u64,
}

impl MixedSource {
    pub fn new(total_rps: f64, duration_s: f64, seed: u64) -> MixedSource {
        let per = total_rps / 4.0;
        let mut subs: Vec<SpecSource> = base_families()
            .into_iter()
            .enumerate()
            .map(|(i, fam)| SpecSource::new(fam.spec(per, duration_s), seed.wrapping_add(i as u64 * 7919)))
            .collect();
        let peeked = subs.iter_mut().map(|s| s.next_request()).collect();
        MixedSource {
            subs,
            peeked,
            total_rps,
            duration_s,
            next_id: 0,
        }
    }
}

impl ArrivalSource for MixedSource {
    fn next_request(&mut self) -> Option<Request> {
        let mut best: Option<usize> = None;
        for (i, p) in self.peeked.iter().enumerate() {
            if let Some(r) = p {
                let better = match best {
                    None => true,
                    Some(b) => r.arrival < self.peeked[b].as_ref().unwrap().arrival,
                };
                if better {
                    best = Some(i);
                }
            }
        }
        let b = best?;
        let mut r = self.peeked[b].take().unwrap();
        self.peeked[b] = self.subs[b].next_request();
        r.id = self.next_id;
        self.next_id += 1;
        Some(r)
    }

    fn duration_s(&self) -> f64 {
        self.duration_s
    }

    fn label(&self) -> String {
        "mixed".into()
    }

    fn profile(&self) -> TraceProfile {
        let fams = base_families();
        let n = fams.len() as f64;
        let mut avg_in = 0.0;
        let mut avg_out = 0.0;
        for fam in fams {
            let s = fam.spec(self.total_rps / n, self.duration_s);
            avg_in += s.input_len.mean() / n;
            avg_out += s.output_len.mean() / n;
        }
        TraceProfile {
            avg_rps: self.total_rps,
            avg_input_tokens: avg_in,
            avg_output_tokens: avg_out,
            duration_s: self.duration_s,
        }
    }
}

/// Build the streaming source for a trace family (the factory the grid
/// runner hands to each worker).
pub fn family_source(family: TraceFamily, rps: f64, duration_s: f64, seed: u64) -> Box<dyn ArrivalSource + Send> {
    if family == TraceFamily::Mixed {
        Box::new(MixedSource::new(rps, duration_s, seed))
    } else {
        spec_source(&family.spec(rps, duration_s), seed)
    }
}

/// [`family_source`] with an optional multi-turn session model layered on
/// top (the scenario loader's `sessions` block). `None` defers to the
/// plain family stream, bit-identical to the historical output; `Some`
/// wraps the family's base arrivals in a
/// [`super::session::SessionSource`] — including the Mixed family, whose
/// interleaved stream becomes the session openers.
pub fn sessioned_family_source(
    family: TraceFamily,
    rps: f64,
    duration_s: f64,
    seed: u64,
    sessions: Option<SessionModel>,
) -> Box<dyn ArrivalSource + Send> {
    let Some(model) = sessions else {
        return family_source(family, rps, duration_s, seed);
    };
    let spec = family.spec(rps, duration_s).with_sessions(model);
    if family == TraceFamily::Mixed {
        let base = MixedSource::new(rps, duration_s, seed);
        Box::new(super::session::SessionSource::new(&spec, base, seed))
    } else {
        spec_source(&spec, seed)
    }
}

/// Build the streaming source for an arbitrary [`TraceSpec`], wrapping in
/// the multi-turn [`super::session::SessionSource`] when the spec carries
/// a session model. Specs with `sessions: None` go through the bare
/// [`SpecSource`] path, bit-identical to the historical stream.
pub fn spec_source(spec: &TraceSpec, seed: u64) -> Box<dyn ArrivalSource + Send> {
    let base = SpecSource::new(spec.clone(), seed);
    if spec.sessions.is_some() {
        Box::new(super::session::SessionSource::new(spec, base, seed))
    } else {
        Box::new(base)
    }
}

/// Generate a materialized trace from a spec. Deterministic for a given
/// seed; drains [`SpecSource`] (session-wrapped when the spec asks for
/// it), whose sequence is pinned to the old eager generator by the
/// streaming-equivalence tests.
pub fn generate(spec: &TraceSpec, seed: u64) -> Trace {
    materialize(&mut spec_source(spec, seed))
}

/// Generate a materialized family trace at the given rate/duration.
pub fn generate_family(family: TraceFamily, rps: f64, duration_s: f64, seed: u64) -> Trace {
    let mut src = family_source(family, rps, duration_s, seed);
    materialize(&mut src)
}

/// The paper's Mixed trace, materialized (see [`MixedSource`]).
pub fn generate_mixed(total_rps: f64, duration_s: f64, seed: u64) -> Trace {
    materialize(&mut MixedSource::new(total_rps, duration_s, seed))
}

/// A step trace: stable `base_rps`, jumping to `burst_rps` during
/// [t_start, t_start + burst_len), then back — the §II-C2 and Fig. 10
/// microbenchmark shape. Lengths are fixed for determinism.
#[allow(clippy::too_many_arguments)]
pub fn step_trace(
    base_rps: f64,
    burst_rps: f64,
    t_start: f64,
    burst_len: f64,
    duration_s: f64,
    input_tokens: usize,
    output_tokens: usize,
    seed: u64,
) -> Trace {
    let mut rng = Pcg64::new(seed);
    let mut requests = Vec::new();
    let mut t = 0.0;
    let mut id = 0u64;
    while t < duration_s {
        let rate = if t >= t_start && t < t_start + burst_len {
            burst_rps
        } else {
            base_rps
        };
        t += rng.exponential(rate);
        if t >= duration_s {
            break;
        }
        requests.push(Request::new(id, t, input_tokens, output_tokens));
        id += 1;
    }
    Trace {
        name: format!("step-{base_rps}to{burst_rps}"),
        duration_s,
        requests,
    }
}

/// Uniform nine-bucket mix at the given request rate: Poisson arrivals
/// cycling through the bucket representatives in order (§VI-B1 decoder-
/// count validation; also the `uniform-buckets` scenario workload).
pub fn uniform_bucket_trace(rps: f64, duration_s: f64, seed: u64) -> Trace {
    let scheme = crate::workload::BucketScheme::default();
    let buckets = crate::workload::all_buckets();
    let mut rng = Pcg64::new(seed);
    let mut requests = Vec::new();
    let mut t = 0.0;
    let mut id = 0u64;
    loop {
        t += rng.exponential(rps);
        if t >= duration_s {
            break;
        }
        let b = buckets[(id as usize) % buckets.len()];
        let (input, output) = scheme.representative(b);
        requests.push(Request::new(id, t, input, output));
        id += 1;
    }
    Trace {
        name: "uniform-9-bucket".into(),
        duration_s,
        requests,
    }
}

/// The Fig. 6 toy workload: two bursts over stable traffic — at `t1`
/// five 2-token requests (request burst), at `t2` two 5-token requests
/// (token burst).
pub fn fig6_trace(t1: f64, t2: f64, duration_s: f64) -> Trace {
    let mut requests = Vec::new();
    let mut id = 0u64;
    // stable background: 1 request of 1 token every second
    let mut t = 0.5;
    while t < duration_s {
        requests.push(Request::new(id, t, 1, 8));
        id += 1;
        t += 1.0;
    }
    for i in 0..5 {
        requests.push(Request::new(id, t1 + i as f64 * 1e-3, 2, 8));
        id += 1;
    }
    for i in 0..2 {
        requests.push(Request::new(id, t2 + i as f64 * 1e-3, 5, 8));
        id += 1;
    }
    requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    Trace {
        name: "fig6-two-bursts".into(),
        duration_s,
        requests,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sessioned_streaming_matches_materialized() {
        // The streamed SessionSource and the materialized trace built by
        // draining it must agree request-for-request, session refs
        // included — the same contract the sessionless streaming-
        // equivalence tests pin for SpecSource.
        let spec = TraceFamily::AzureConv
            .spec(6.0, 120.0)
            .with_sessions(SessionModel::new(4.0, 5.0));
        let eager = generate(&spec, 9);
        let mut src = spec_source(&spec, 9);
        let mut streamed = Vec::new();
        while let Some(r) = src.next_request() {
            streamed.push(r);
        }
        assert_eq!(eager.requests, streamed);
        assert!(
            streamed
                .iter()
                .any(|r| r.session.is_some_and(|s| s.prefix_tokens > 0)),
            "session layer produced no warm follow-up turns"
        );
        // And the sessioned helper routes through the same wrapped path.
        let mut via_family = sessioned_family_source(
            TraceFamily::AzureConv,
            6.0,
            120.0,
            9,
            Some(SessionModel::new(4.0, 5.0)),
        );
        let mut family_reqs = Vec::new();
        while let Some(r) = via_family.next_request() {
            family_reqs.push(r);
        }
        assert_eq!(family_reqs, streamed);
    }

    #[test]
    fn generated_rate_matches_spec() {
        // Full diurnal period so the sinusoidal modulation integrates out.
        let spec = TraceFamily::AzureConv.spec(22.0, 900.0);
        let t = generate(&spec, 1);
        let rps = t.avg_rps();
        assert!((rps - 22.0).abs() < 3.0, "rps={rps}");
    }

    #[test]
    fn deterministic_for_seed() {
        let spec = TraceFamily::AzureCode.spec(10.0, 60.0);
        let a = generate(&spec, 7);
        let b = generate(&spec, 7);
        assert_eq!(a.requests, b.requests);
        let c = generate(&spec, 8);
        assert_ne!(a.requests.len(), 0);
        assert!(a.requests != c.requests);
    }

    #[test]
    fn streaming_source_matches_materialized() {
        let spec = TraceFamily::BurstGpt1.spec(8.0, 90.0);
        let eager = generate(&spec, 5);
        let mut src = SpecSource::new(spec, 5);
        let mut streamed = Vec::new();
        while let Some(r) = src.next_request() {
            streamed.push(r);
        }
        assert_eq!(streamed, eager.requests);
    }

    #[test]
    fn arrivals_sorted_and_bounded() {
        let spec = TraceFamily::BurstGpt2.spec(15.0, 120.0);
        let t = generate(&spec, 3);
        for w in t.requests.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        assert!(t.requests.iter().all(|r| r.arrival < 120.0));
        assert!(t.requests.iter().all(|r| r.input_tokens >= 4));
        assert!(t.requests.iter().all(|r| r.input_tokens <= 8192));
    }

    #[test]
    fn mixed_combines_families() {
        let t = generate_mixed(20.0, 120.0, 5);
        assert!((t.avg_rps() - 20.0).abs() < 4.0, "rps={}", t.avg_rps());
        // IDs reassigned contiguous
        assert_eq!(t.requests.first().unwrap().id, 0);
        assert_eq!(t.requests.last().unwrap().id as usize, t.requests.len() - 1);
        for w in t.requests.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn resample_halves_rate() {
        let spec = TraceFamily::AzureConv.spec(20.0, 200.0);
        let t = generate(&spec, 11);
        let mut rng = Pcg64::new(1);
        let half = t.resample_to_rps(10.0, &mut rng);
        assert!((half.avg_rps() - 10.0).abs() < 1.5, "rps={}", half.avg_rps());
    }

    #[test]
    fn resample_duplication_stays_sorted_with_sequential_ids() {
        // Regression: the old duplication path jittered copies after id
        // assignment and sorted afterwards, leaving ids out of arrival
        // order. Sort-and-compare must now be a no-op.
        let spec = TraceFamily::AzureConv.spec(8.0, 120.0);
        let t = generate(&spec, 21);
        let mut rng = Pcg64::new(9);
        let up = t.resample_to_rps(24.0, &mut rng);
        assert!((up.avg_rps() - 24.0).abs() < 3.0, "rps={}", up.avg_rps());
        let mut sorted = up.requests.clone();
        sorted.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        assert_eq!(sorted, up.requests, "duplication must keep arrivals time-sorted");
        for (i, r) in up.requests.iter().enumerate() {
            assert_eq!(r.id, i as u64, "ids must be re-sequenced in arrival order");
        }
        // Deterministic from the caller's rng state.
        let mut rng2 = Pcg64::new(9);
        let up2 = t.resample_to_rps(24.0, &mut rng2);
        assert_eq!(up.requests, up2.requests);
    }

    #[test]
    fn step_trace_rates() {
        let t = step_trace(8.0, 16.0, 4.0, 4.0, 12.0, 512, 128, 2);
        let in_burst = t
            .requests
            .iter()
            .filter(|r| r.arrival >= 4.0 && r.arrival < 8.0)
            .count() as f64
            / 4.0;
        let stable = t
            .requests
            .iter()
            .filter(|r| r.arrival < 4.0)
            .count() as f64
            / 4.0;
        assert!(in_burst > stable, "burst={in_burst} stable={stable}");
    }

    #[test]
    fn fig6_trace_structure() {
        let t = fig6_trace(3.0, 7.0, 10.0);
        let at_t1 = t
            .requests
            .iter()
            .filter(|r| (r.arrival - 3.0).abs() < 0.01 && r.input_tokens == 2)
            .count();
        let at_t2 = t
            .requests
            .iter()
            .filter(|r| (r.arrival - 7.0).abs() < 0.01 && r.input_tokens == 5)
            .count();
        assert_eq!(at_t1, 5);
        assert_eq!(at_t2, 2);
    }
}
