//! Trace generation: turn a [`TraceSpec`] into a concrete, time-ordered
//! request sequence, plus the synthetic step/burst traces used by the
//! paper's microbenchmarks (Figs. 4, 6, 10).

use super::spec::{base_families, TraceFamily, TraceSpec};
use crate::util::rng::Pcg64;
use crate::workload::Request;

/// A generated trace: time-sorted requests plus its spec for reporting.
#[derive(Clone, Debug)]
pub struct Trace {
    pub name: String,
    pub duration_s: f64,
    pub requests: Vec<Request>,
}

impl Trace {
    pub fn avg_rps(&self) -> f64 {
        if self.duration_s <= 0.0 {
            return 0.0;
        }
        self.requests.len() as f64 / self.duration_s
    }

    pub fn avg_input_tokens(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().map(|r| r.input_tokens as f64).sum::<f64>()
            / self.requests.len() as f64
    }

    pub fn avg_output_tokens(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().map(|r| r.output_tokens as f64).sum::<f64>()
            / self.requests.len() as f64
    }

    /// Input-token arrival rate averaged over the whole trace (tok/s).
    pub fn avg_input_tps(&self) -> f64 {
        if self.duration_s <= 0.0 {
            return 0.0;
        }
        self.requests.iter().map(|r| r.input_tokens as f64).sum::<f64>() / self.duration_s
    }

    /// Resample to a target average RPS by uniform thinning (the paper's
    /// §V sampling to 22 RPS) or by duplication with jitter when the target
    /// exceeds the source rate.
    pub fn resample_to_rps(&self, target_rps: f64, rng: &mut Pcg64) -> Trace {
        let cur = self.avg_rps();
        if cur <= 0.0 {
            return self.clone();
        }
        let keep = target_rps / cur;
        let mut requests = Vec::new();
        let mut id = 0u64;
        for r in &self.requests {
            let mut copies = keep.floor() as usize;
            if rng.f64() < keep - keep.floor() {
                copies += 1;
            }
            for c in 0..copies {
                let jitter = if c == 0 { 0.0 } else { rng.range_f64(0.0, 0.050) };
                let mut nr = r.clone();
                nr.id = id;
                nr.arrival = (r.arrival + jitter).min(self.duration_s);
                id += 1;
                requests.push(nr);
            }
        }
        requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        Trace {
            name: self.name.clone(),
            duration_s: self.duration_s,
            requests,
        }
    }
}

fn sample_len(rng: &mut Pcg64, d: &super::spec::LenDist) -> usize {
    (rng.lognormal(d.mu, d.sigma).round() as usize).clamp(d.min, d.max)
}

/// Generate a trace from a spec. Deterministic for a given seed.
///
/// The arrival process is a two-state Markov-modulated Gamma renewal
/// process: stable ↔ burst episodes (Exp-distributed lengths), with the
/// stable/burst rates solved so that the long-run average hits `spec.rps`
/// and the burst occupancy matches `spec.burst.time_fraction`. A slow
/// sinusoid modulates both, giving the trend the paper's running-average
/// plots show.
pub fn generate(spec: &TraceSpec, seed: u64) -> Trace {
    let mut rng = Pcg64::new(seed);
    let mut arrivals_rng = rng.fork();
    let mut len_rng = rng.fork();
    let mut episode_rng = rng.fork();

    let bf = &spec.burst;
    // Solve stable rate r_s from: f*k*r_s + (1-f)*r_s = rps
    let r_stable = spec.rps / (bf.time_fraction * bf.rate_factor + (1.0 - bf.time_fraction));
    let r_burst = r_stable * bf.rate_factor;
    // Episode dynamics: mean burst length given; mean stable gap from
    // occupancy: f = mean_burst / (mean_burst + mean_stable).
    let mean_stable_gap = if bf.time_fraction > 0.0 {
        bf.mean_len_s * (1.0 - bf.time_fraction) / bf.time_fraction
    } else {
        f64::INFINITY
    };

    let mut requests = Vec::with_capacity((spec.rps * spec.duration_s) as usize + 16);
    let mut t = 0.0f64;
    let mut in_burst = false;
    let mut phase_end = if mean_stable_gap.is_finite() {
        episode_rng.exponential(1.0 / mean_stable_gap)
    } else {
        f64::INFINITY
    };
    let mut id = 0u64;

    while t < spec.duration_s {
        // Advance episode state machine past `t`.
        while t >= phase_end {
            in_burst = !in_burst;
            let mean = if in_burst { bf.mean_len_s } else { mean_stable_gap };
            phase_end += episode_rng.exponential(1.0 / mean);
        }
        let diurnal =
            1.0 + spec.diurnal_amplitude * (2.0 * std::f64::consts::PI * t / spec.diurnal_period_s).sin();
        let rate = (if in_burst { r_burst } else { r_stable }) * diurnal.max(0.05);
        // Gamma renewal with shape k and mean 1/rate → scale = 1/(k*rate).
        let k = spec.arrival_shape;
        let gap = arrivals_rng.gamma(k, 1.0 / (k * rate));
        t += gap;
        if t >= spec.duration_s {
            break;
        }
        let input = sample_len(&mut len_rng, &spec.input_len);
        let output = sample_len(&mut len_rng, &spec.output_len);
        requests.push(Request::new(id, t, input, output));
        id += 1;
    }

    Trace {
        name: spec.name.clone(),
        duration_s: spec.duration_s,
        requests,
    }
}

/// Generate a family trace at the given rate/duration.
pub fn generate_family(family: TraceFamily, rps: f64, duration_s: f64, seed: u64) -> Trace {
    if family == TraceFamily::Mixed {
        return generate_mixed(rps, duration_s, seed);
    }
    generate(&family.spec(rps, duration_s), seed)
}

/// The paper's Mixed trace: Azure Conversation + Azure Code + BurstGPT 1/2
/// interleaved at equal request rates (§V Workload Generation).
pub fn generate_mixed(total_rps: f64, duration_s: f64, seed: u64) -> Trace {
    let per = total_rps / 4.0;
    let mut requests = Vec::new();
    for (i, fam) in base_families().into_iter().enumerate() {
        let sub = generate(&fam.spec(per, duration_s), seed.wrapping_add(i as u64 * 7919));
        requests.extend(sub.requests);
    }
    requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    for (i, r) in requests.iter_mut().enumerate() {
        r.id = i as u64;
    }
    Trace {
        name: "mixed".into(),
        duration_s,
        requests,
    }
}

/// A step trace: stable `base_rps`, jumping to `burst_rps` during
/// [t_start, t_start + burst_len), then back — the §II-C2 and Fig. 10
/// microbenchmark shape. Lengths are fixed for determinism.
pub fn step_trace(
    base_rps: f64,
    burst_rps: f64,
    t_start: f64,
    burst_len: f64,
    duration_s: f64,
    input_tokens: usize,
    output_tokens: usize,
    seed: u64,
) -> Trace {
    let mut rng = Pcg64::new(seed);
    let mut requests = Vec::new();
    let mut t = 0.0;
    let mut id = 0u64;
    while t < duration_s {
        let rate = if t >= t_start && t < t_start + burst_len {
            burst_rps
        } else {
            base_rps
        };
        t += rng.exponential(rate);
        if t >= duration_s {
            break;
        }
        requests.push(Request::new(id, t, input_tokens, output_tokens));
        id += 1;
    }
    Trace {
        name: format!("step-{base_rps}to{burst_rps}"),
        duration_s,
        requests,
    }
}

/// The Fig. 6 toy workload: two bursts over stable traffic — at `t1`
/// five 2-token requests (request burst), at `t2` two 5-token requests
/// (token burst).
pub fn fig6_trace(t1: f64, t2: f64, duration_s: f64) -> Trace {
    let mut requests = Vec::new();
    let mut id = 0u64;
    // stable background: 1 request of 1 token every second
    let mut t = 0.5;
    while t < duration_s {
        requests.push(Request::new(id, t, 1, 8));
        id += 1;
        t += 1.0;
    }
    for i in 0..5 {
        requests.push(Request::new(id, t1 + i as f64 * 1e-3, 2, 8));
        id += 1;
    }
    for i in 0..2 {
        requests.push(Request::new(id, t2 + i as f64 * 1e-3, 5, 8));
        id += 1;
    }
    requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    Trace {
        name: "fig6-two-bursts".into(),
        duration_s,
        requests,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_rate_matches_spec() {
        // Full diurnal period so the sinusoidal modulation integrates out.
        let spec = TraceFamily::AzureConv.spec(22.0, 900.0);
        let t = generate(&spec, 1);
        let rps = t.avg_rps();
        assert!((rps - 22.0).abs() < 3.0, "rps={rps}");
    }

    #[test]
    fn deterministic_for_seed() {
        let spec = TraceFamily::AzureCode.spec(10.0, 60.0);
        let a = generate(&spec, 7);
        let b = generate(&spec, 7);
        assert_eq!(a.requests, b.requests);
        let c = generate(&spec, 8);
        assert_ne!(a.requests.len(), 0);
        assert!(a.requests != c.requests);
    }

    #[test]
    fn arrivals_sorted_and_bounded() {
        let spec = TraceFamily::BurstGpt2.spec(15.0, 120.0);
        let t = generate(&spec, 3);
        for w in t.requests.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        assert!(t.requests.iter().all(|r| r.arrival < 120.0));
        assert!(t.requests.iter().all(|r| r.input_tokens >= 4));
        assert!(t.requests.iter().all(|r| r.input_tokens <= 8192));
    }

    #[test]
    fn mixed_combines_families() {
        let t = generate_mixed(20.0, 120.0, 5);
        assert!((t.avg_rps() - 20.0).abs() < 4.0, "rps={}", t.avg_rps());
        // IDs reassigned contiguous
        assert_eq!(t.requests.first().unwrap().id, 0);
        assert_eq!(t.requests.last().unwrap().id as usize, t.requests.len() - 1);
    }

    #[test]
    fn resample_halves_rate() {
        let spec = TraceFamily::AzureConv.spec(20.0, 200.0);
        let t = generate(&spec, 11);
        let mut rng = Pcg64::new(1);
        let half = t.resample_to_rps(10.0, &mut rng);
        assert!((half.avg_rps() - 10.0).abs() < 1.5, "rps={}", half.avg_rps());
    }

    #[test]
    fn step_trace_rates() {
        let t = step_trace(8.0, 16.0, 4.0, 4.0, 12.0, 512, 128, 2);
        let in_burst = t
            .requests
            .iter()
            .filter(|r| r.arrival >= 4.0 && r.arrival < 8.0)
            .count() as f64
            / 4.0;
        let stable = t
            .requests
            .iter()
            .filter(|r| r.arrival < 4.0)
            .count() as f64
            / 4.0;
        assert!(in_burst > stable, "burst={in_burst} stable={stable}");
    }

    #[test]
    fn fig6_trace_structure() {
        let t = fig6_trace(3.0, 7.0, 10.0);
        let at_t1 = t
            .requests
            .iter()
            .filter(|r| (r.arrival - 3.0).abs() < 0.01 && r.input_tokens == 2)
            .count();
        let at_t2 = t
            .requests
            .iter()
            .filter(|r| (r.arrival - 7.0).abs() < 0.01 && r.input_tokens == 5)
            .count();
        assert_eq!(at_t1, 5);
        assert_eq!(at_t2, 2);
    }
}
