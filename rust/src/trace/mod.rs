//! Synthetic workload traces.
//!
//! Substitute for the paper's Azure LLM-inference and BurstGPT production
//! traces (unavailable offline): parameterized generators reproducing the
//! published burstiness and length statistics, plus the running-average
//! burst analytics of §II-C1.

pub mod burst;
pub mod gen;
pub mod spec;

pub use gen::{fig6_trace, generate, generate_family, generate_mixed, step_trace, Trace};
pub use spec::{base_families, BurstModel, LenDist, TraceFamily, TraceSpec};
