//! Workload traces: streaming arrival pipeline + synthetic generators.
//!
//! Substitute for the paper's Azure LLM-inference and BurstGPT production
//! traces (unavailable offline): parameterized streaming generators
//! reproducing the published burstiness and length statistics
//! ([`gen::SpecSource`]), a replay loader for Azure-style CSV/JSONL trace
//! files ([`replay`]), composable transform combinators ([`transform`]),
//! and the running-average burst analytics of §II-C1 ([`burst`]).
//!
//! Everything downstream consumes the pull-based [`ArrivalSource`] trait;
//! [`materialize`] bridges to the eager [`Trace`] container where a full
//! vector is genuinely needed.

pub mod burst;
pub mod gen;
pub mod replay;
pub mod session;
pub mod source;
pub mod spec;
pub mod transform;

pub use gen::{
    family_source, fig6_trace, generate, generate_family, generate_mixed, sessioned_family_source,
    spec_source,
    step_trace, uniform_bucket_trace, MixedSource, SpecSource, Trace,
};
pub use session::SessionSource;
pub use source::{
    fast_forward, materialize, ArrivalSource, OwnedTraceSource, SourceFactory, TraceProfile,
    TraceReplaySource, TraceSliceSource,
};
pub use spec::{base_families, BurstModel, LenDist, SessionModel, TraceFamily, TraceSpec};
pub use transform::{BurstInject, BurstWindow, Diurnal, RateScale, Resample, SourceExt, Window};
