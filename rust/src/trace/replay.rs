//! Trace replay: load and save Azure-LLM-style trace files.
//!
//! Two formats, no external dependencies (the JSONL path reuses
//! `util/json.rs`):
//!
//! - **CSV** — a header row naming an arrival-time column and the two
//!   token-count columns, then one record per line. Header aliases match
//!   the public Azure LLM inference traces (`TIMESTAMP`,
//!   `ContextTokens`, `GeneratedTokens`) as well as our canonical
//!   `arrival_s,input_tokens,output_tokens`. Lines starting with `#` are
//!   comments; a `# duration_s=<x>` comment pins the trace horizon.
//! - **JSONL** — one JSON object per line with the same field aliases. A
//!   record containing `duration_s` and no arrival field is metadata.
//!
//! Without explicit metadata the horizon defaults to the last arrival
//! rounded up to a whole second. Records are stably sorted by arrival and
//! ids are re-sequenced 0..n on load, so a save → load round trip of any
//! well-formed trace (sorted, sequential ids) is lossless: arrival times
//! are emitted with Rust's shortest-round-trip float formatting.

use super::gen::Trace;
use crate::util::json::Json;
use crate::workload::Request;
use std::collections::HashMap;
use std::path::Path;

/// Column aliases accepted for each field (lowercased for matching).
const ARRIVAL_KEYS: &[&str] = &["arrival_s", "arrival", "timestamp", "ts", "time"];
const INPUT_KEYS: &[&str] = &["input_tokens", "contexttokens", "context_tokens", "prompt_tokens", "input"];
const OUTPUT_KEYS: &[&str] = &["output_tokens", "generatedtokens", "generated_tokens", "output"];
/// Optional multi-turn columns (`sim::kvcache` workloads). Azure-style
/// exports carry a conversation id; the prefix column is ours.
const SESSION_KEYS: &[&str] = &["session_id", "session", "conversationid", "conversation_id", "conv_id"];
const PREFIX_KEYS: &[&str] = &["prefix_tokens", "prefix", "cached_tokens", "cachedtokens"];

fn match_key(name: &str, aliases: &[&str]) -> bool {
    let n = name.trim().to_ascii_lowercase();
    aliases.iter().any(|a| *a == n)
}

/// Map a session-id cell to a `u64`: decimal ids pass through exactly
/// (lossless round trips), anything else (GUID-style conversation keys)
/// hashes deterministically via FNV-1a.
fn session_id_of(text: &str) -> u64 {
    let t = text.trim();
    if let Ok(v) = t.parse::<u64>() {
        return v;
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in t.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// One parsed record before id re-sequencing.
struct Row {
    arrival: f64,
    input: usize,
    output: usize,
    session: Option<u64>,
    /// Explicit warm-prefix length; `None` with a session id present means
    /// "derive from the running conversation context".
    prefix: Option<usize>,
}

/// Finalize parsed rows into a [`Trace`]: stable-sort by arrival,
/// re-sequence ids, resolve the horizon, and derive missing prefixes.
///
/// Prefix derivation: turn *k* of a conversation resends everything said
/// so far, so when a file carries session ids without a prefix column the
/// warm prefix defaults to the previous turn's input + output tokens
/// (clamped to the prompt length by [`Request::with_session`]).
fn finish(name: &str, mut rows: Vec<Row>, duration_s: Option<f64>) -> anyhow::Result<Trace> {
    anyhow::ensure!(!rows.is_empty(), "replay file contains no records");
    rows.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap_or(std::cmp::Ordering::Equal));
    let last = rows.last().map(|r| r.arrival).unwrap_or(0.0);
    let duration = duration_s.unwrap_or_else(|| last.ceil().max(1.0));
    anyhow::ensure!(
        duration.is_finite() && duration > 0.0,
        "declared duration_s {duration} must be finite and positive"
    );
    anyhow::ensure!(
        duration >= last,
        "declared duration_s {duration} precedes last arrival {last}"
    );
    let mut context: HashMap<u64, usize> = HashMap::new();
    let requests = rows
        .into_iter()
        .enumerate()
        .map(|(i, r)| {
            let mut req = Request::new(i as u64, r.arrival, r.input, r.output);
            if let Some(id) = r.session {
                let prefix = r
                    .prefix
                    .unwrap_or_else(|| context.get(&id).copied().unwrap_or(0));
                req = req.with_session(id, prefix);
                context.insert(id, r.input + r.output);
            }
            req
        })
        .collect();
    Ok(Trace {
        name: name.to_string(),
        duration_s: duration,
        requests,
    })
}

/// Parse a `# key=value` comment; returns the declared duration if the
/// line carries one.
fn comment_duration(line: &str) -> Option<f64> {
    let body = line.trim_start_matches('#').trim();
    for part in body.split_whitespace() {
        if let Some(v) = part.strip_prefix("duration_s=") {
            return v.parse::<f64>().ok();
        }
    }
    None
}

/// Parse CSV replay text into a trace named `name`.
pub fn parse_csv(text: &str, name: &str) -> anyhow::Result<Trace> {
    let mut duration: Option<f64> = None;
    let mut cols: Option<(usize, usize, usize)> = None;
    // Optional session/prefix columns; empty cells mean "sessionless row".
    let mut opt_cols: (Option<usize>, Option<usize>) = (None, None);
    let mut rows: Vec<Row> = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('#') {
            if let Some(d) = comment_duration(line) {
                duration = Some(d);
            }
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        if cols.is_none() {
            // Header row: locate each column by alias. A header is
            // required — Azure-style exports always carry one.
            let find = |aliases: &[&str]| fields.iter().position(|f| match_key(f, aliases));
            let (Some(a), Some(i), Some(o)) = (find(ARRIVAL_KEYS), find(INPUT_KEYS), find(OUTPUT_KEYS)) else {
                anyhow::bail!(
                    "line {}: CSV header must name arrival/input/output columns \
                     (e.g. `arrival_s,input_tokens,output_tokens`), got `{line}`",
                    lineno + 1
                );
            };
            cols = Some((a, i, o));
            opt_cols = (find(SESSION_KEYS), find(PREFIX_KEYS));
            continue;
        }
        let (a, i, o) = cols.unwrap();
        let need = a.max(i).max(o);
        anyhow::ensure!(
            fields.len() > need,
            "line {}: expected at least {} comma-separated fields, got {}",
            lineno + 1,
            need + 1,
            fields.len()
        );
        let arrival: f64 = fields[a]
            .parse()
            .map_err(|_| anyhow::anyhow!("line {}: bad arrival `{}`", lineno + 1, fields[a]))?;
        let input: usize = fields[i]
            .parse()
            .map_err(|_| anyhow::anyhow!("line {}: bad input tokens `{}`", lineno + 1, fields[i]))?;
        let output: usize = fields[o]
            .parse()
            .map_err(|_| anyhow::anyhow!("line {}: bad output tokens `{}`", lineno + 1, fields[o]))?;
        anyhow::ensure!(
            arrival.is_finite() && arrival >= 0.0,
            "line {}: arrival must be finite and >= 0",
            lineno + 1
        );
        let cell = |ix: Option<usize>| {
            ix.and_then(|ix| fields.get(ix))
                .map(|f| f.trim())
                .filter(|f| !f.is_empty())
        };
        let session = cell(opt_cols.0).map(session_id_of);
        let prefix = match cell(opt_cols.1) {
            Some(f) if session.is_some() => Some(f.parse::<usize>().map_err(|_| {
                anyhow::anyhow!("line {}: bad prefix tokens `{f}`", lineno + 1)
            })?),
            // A prefix without a session id is meaningless; ignore it.
            _ => None,
        };
        rows.push(Row {
            arrival,
            input,
            output,
            session,
            prefix,
        });
    }
    finish(name, rows, duration)
}

/// Pull a numeric field from a JSON object by alias list.
fn json_field(obj: &Json, aliases: &[&str]) -> Option<f64> {
    json_raw(obj, aliases).and_then(Json::as_f64)
}

/// Pull a raw field value from a JSON object by alias list.
fn json_raw<'a>(obj: &'a Json, aliases: &[&str]) -> Option<&'a Json> {
    if let Json::Obj(m) = obj {
        for (k, v) in m {
            if match_key(k, aliases) {
                return Some(v);
            }
        }
    }
    None
}

/// Parse JSONL replay text into a trace named `name`.
pub fn parse_jsonl(text: &str, name: &str) -> anyhow::Result<Trace> {
    let mut duration: Option<f64> = None;
    let mut rows: Vec<Row> = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('#') {
            if let Some(d) = comment_duration(line) {
                duration = Some(d);
            }
            continue;
        }
        let obj = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("line {}: invalid JSON record: {e}", lineno + 1))?;
        let arrival = json_field(&obj, ARRIVAL_KEYS);
        if arrival.is_none() {
            // Metadata record (e.g. `{"duration_s": 7200}`).
            if let Some(d) = obj.get("duration_s").and_then(Json::as_f64) {
                duration = Some(d);
                continue;
            }
            anyhow::bail!("line {}: record has no arrival field", lineno + 1);
        }
        let arrival = arrival.unwrap();
        let input = json_field(&obj, INPUT_KEYS)
            .ok_or_else(|| anyhow::anyhow!("line {}: record has no input-token field", lineno + 1))?;
        let output = json_field(&obj, OUTPUT_KEYS)
            .ok_or_else(|| anyhow::anyhow!("line {}: record has no output-token field", lineno + 1))?;
        anyhow::ensure!(
            arrival.is_finite() && arrival >= 0.0,
            "line {}: arrival must be finite and >= 0",
            lineno + 1
        );
        // Match the CSV path's strictness: token counts must be
        // non-negative integers (a bare `as usize` would silently
        // saturate -100 to 0 and truncate 10.7 to 10).
        for (label, v) in [("input", input), ("output", output)] {
            anyhow::ensure!(
                v.is_finite() && v >= 0.0 && v.fract() == 0.0,
                "line {}: {label} tokens must be a non-negative integer, got {v}",
                lineno + 1
            );
        }
        let session = match json_raw(&obj, SESSION_KEYS) {
            None | Some(Json::Null) => None,
            Some(Json::Str(s)) => Some(session_id_of(s)),
            Some(v) => {
                let f = v.as_f64().ok_or_else(|| {
                    anyhow::anyhow!("line {}: session id must be a string or number", lineno + 1)
                })?;
                anyhow::ensure!(
                    f.is_finite() && f >= 0.0 && f.fract() == 0.0,
                    "line {}: numeric session id must be a non-negative integer, got {f}",
                    lineno + 1
                );
                Some(f as u64)
            }
        };
        let prefix = match json_field(&obj, PREFIX_KEYS) {
            Some(p) if session.is_some() => {
                anyhow::ensure!(
                    p.is_finite() && p >= 0.0 && p.fract() == 0.0,
                    "line {}: prefix tokens must be a non-negative integer, got {p}",
                    lineno + 1
                );
                Some(p as usize)
            }
            _ => None,
        };
        rows.push(Row {
            arrival,
            input: input as usize,
            output: output as usize,
            session,
            prefix,
        });
    }
    finish(name, rows, duration)
}

/// Serialize a trace to canonical CSV (`# duration_s` comment + header +
/// one row per request, shortest-round-trip floats).
pub fn to_csv(trace: &Trace) -> String {
    // Session columns appear only when some request carries one, so
    // sessionless traces serialize byte-identically to the historical
    // three-column format.
    let sessions = trace.requests.iter().any(|r| r.session.is_some());
    let mut out = String::new();
    out.push_str(&format!("# duration_s={}\n", trace.duration_s));
    if sessions {
        out.push_str("arrival_s,input_tokens,output_tokens,session_id,prefix_tokens\n");
    } else {
        out.push_str("arrival_s,input_tokens,output_tokens\n");
    }
    for r in &trace.requests {
        match (sessions, r.session) {
            (false, _) => {
                out.push_str(&format!("{},{},{}\n", r.arrival, r.input_tokens, r.output_tokens))
            }
            (true, Some(s)) => out.push_str(&format!(
                "{},{},{},{},{}\n",
                r.arrival, r.input_tokens, r.output_tokens, s.id, s.prefix_tokens
            )),
            (true, None) => out.push_str(&format!(
                "{},{},{},,\n",
                r.arrival, r.input_tokens, r.output_tokens
            )),
        }
    }
    out
}

/// Serialize a trace to canonical JSONL (metadata record first).
pub fn to_jsonl(trace: &Trace) -> String {
    let mut out = String::new();
    out.push_str(&Json::obj().set("duration_s", trace.duration_s).to_string());
    out.push('\n');
    for r in &trace.requests {
        let mut rec = Json::obj()
            .set("arrival_s", r.arrival)
            .set("input_tokens", r.input_tokens)
            .set("output_tokens", r.output_tokens);
        if let Some(s) = r.session {
            // Decimal string: hashed conversation keys use all 64 bits,
            // which a JSON double cannot represent exactly.
            rec = rec
                .set("session_id", s.id.to_string())
                .set("prefix_tokens", s.prefix_tokens);
        }
        out.push_str(&rec.to_string());
        out.push('\n');
    }
    out
}

/// Does the path look like JSONL (vs CSV)?
fn is_jsonl(path: &Path) -> bool {
    matches!(
        path.extension().and_then(|e| e.to_str()).map(|e| e.to_ascii_lowercase()).as_deref(),
        Some("jsonl") | Some("ndjson") | Some("json")
    )
}

fn stem_name(path: &Path) -> String {
    path.file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("replay")
        .to_string()
}

/// Load a replay file, dispatching on extension (`.csv` vs
/// `.jsonl`/`.ndjson`/`.json`); unknown extensions are sniffed from the
/// first non-comment byte.
pub fn load_path(path: &Path) -> anyhow::Result<Trace> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    let name = stem_name(path);
    if is_jsonl(path) {
        return parse_jsonl(&text, &name);
    }
    if path.extension().and_then(|e| e.to_str()).map(|e| e.eq_ignore_ascii_case("csv")) == Some(true) {
        return parse_csv(&text, &name);
    }
    let first = text
        .lines()
        .map(str::trim)
        .find(|l| !l.is_empty() && !l.starts_with('#'));
    match first {
        Some(l) if l.starts_with('{') => parse_jsonl(&text, &name),
        _ => parse_csv(&text, &name),
    }
}

/// Save a trace to `path`, format chosen by extension (CSV unless the
/// extension says JSONL).
pub fn save_path(path: &Path, trace: &Trace) -> anyhow::Result<()> {
    let text = if is_jsonl(path) {
        to_jsonl(trace)
    } else {
        to_csv(trace)
    };
    std::fs::write(path, text).map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::gen::generate;
    use crate::trace::spec::TraceFamily;

    fn sample() -> Trace {
        generate(&TraceFamily::AzureConv.spec(5.0, 60.0), 3)
    }

    #[test]
    fn csv_round_trip_is_lossless() {
        let t = sample();
        let text = to_csv(&t);
        let back = parse_csv(&text, &t.name).unwrap();
        assert_eq!(back.requests, t.requests);
        assert_eq!(back.duration_s, t.duration_s);
        // Stable canonical form: serialize(parse(serialize(x))) == serialize(x).
        assert_eq!(to_csv(&back), text);
    }

    #[test]
    fn jsonl_round_trip_is_lossless() {
        let t = sample();
        let text = to_jsonl(&t);
        let back = parse_jsonl(&text, &t.name).unwrap();
        assert_eq!(back.requests, t.requests);
        assert_eq!(back.duration_s, t.duration_s);
        assert_eq!(to_jsonl(&back), text);
    }

    #[test]
    fn cross_format_conversion_preserves_requests() {
        let t = sample();
        let via_jsonl = parse_jsonl(&to_jsonl(&t), "x").unwrap();
        let via_csv = parse_csv(&to_csv(&via_jsonl), "x").unwrap();
        assert_eq!(via_csv.requests, t.requests);
        assert_eq!(via_csv.duration_s, t.duration_s);
    }

    #[test]
    fn azure_style_headers_are_accepted() {
        let text = "TIMESTAMP,ContextTokens,GeneratedTokens\n0.5,100,20\n1.25,300,40\n";
        let t = parse_csv(text, "azure").unwrap();
        assert_eq!(t.requests.len(), 2);
        assert_eq!(t.requests[0].input_tokens, 100);
        assert_eq!(t.requests[1].arrival, 1.25);
        // No metadata: horizon defaults to ceil(last arrival).
        assert_eq!(t.duration_s, 2.0);
    }

    #[test]
    fn unsorted_rows_are_sorted_and_reid_on_load() {
        let text = "arrival_s,input_tokens,output_tokens\n5.0,10,1\n1.0,20,2\n3.0,30,3\n";
        let t = parse_csv(text, "x").unwrap();
        let arr: Vec<f64> = t.requests.iter().map(|r| r.arrival).collect();
        assert_eq!(arr, vec![1.0, 3.0, 5.0]);
        let ids: Vec<u64> = t.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn bad_inputs_are_rejected() {
        assert!(parse_csv("a,b,c\n1,2,3\n", "x").is_err()); // unknown header
        assert!(parse_csv("arrival_s,input_tokens,output_tokens\n", "x").is_err()); // empty
        assert!(parse_csv("arrival_s,input_tokens,output_tokens\n-1,5,5\n", "x").is_err());
        assert!(parse_jsonl("{\"input_tokens\":3}\n", "x").is_err()); // no arrival
        assert!(
            parse_csv("# duration_s=1\narrival_s,input_tokens,output_tokens\n9.0,5,5\n", "x").is_err(),
            "duration before last arrival must be rejected"
        );
        for bad in ["inf", "nan", "-5", "0"] {
            let text = format!("# duration_s={bad}\narrival_s,input_tokens,output_tokens\n0.5,5,5\n");
            assert!(parse_csv(&text, "x").is_err(), "duration_s={bad} must be rejected");
        }
        // JSONL token counts must be non-negative integers, like CSV.
        assert!(parse_jsonl("{\"arrival_s\":1,\"input_tokens\":-100,\"output_tokens\":5}\n", "x").is_err());
        assert!(parse_jsonl("{\"arrival_s\":1,\"input_tokens\":10.7,\"output_tokens\":5}\n", "x").is_err());
    }

    fn sessioned_sample() -> Trace {
        use crate::trace::spec::SessionModel;
        let spec = TraceFamily::AzureConv
            .spec(5.0, 120.0)
            .with_sessions(SessionModel::new(3.0, 4.0));
        generate(&spec, 11)
    }

    #[test]
    fn csv_session_round_trip_is_lossless() {
        let t = sessioned_sample();
        assert!(t.requests.iter().any(|r| r.session.is_some()));
        let text = to_csv(&t);
        assert!(text.contains("session_id"), "sessioned CSV must carry the column");
        let back = parse_csv(&text, &t.name).unwrap();
        assert_eq!(back.requests, t.requests);
        assert_eq!(to_csv(&back), text);
    }

    #[test]
    fn jsonl_session_round_trip_is_lossless() {
        let t = sessioned_sample();
        let text = to_jsonl(&t);
        assert!(text.contains("session_id"));
        let back = parse_jsonl(&text, &t.name).unwrap();
        assert_eq!(back.requests, t.requests);
        assert_eq!(to_jsonl(&back), text);
    }

    #[test]
    fn sessionless_serialization_is_unchanged() {
        // The historical three-column format must stay byte-for-byte:
        // pre-session golden files and diff baselines depend on it.
        let t = sample();
        assert!(to_csv(&t).starts_with(&format!(
            "# duration_s={}\narrival_s,input_tokens,output_tokens\n",
            t.duration_s
        )));
        assert!(!to_jsonl(&t).contains("session_id"));
    }

    #[test]
    fn conversation_ids_without_prefix_column_derive_running_context() {
        // Azure-style export: conversation GUIDs, no prefix column. Turn k
        // should inherit prefix = previous turn's input + output.
        let text = "TIMESTAMP,ContextTokens,GeneratedTokens,ConversationId\n\
                    0.0,100,20,guid-a\n\
                    5.0,140,30,guid-a\n\
                    7.0,50,10,guid-b\n\
                    9.0,300,40,guid-a\n";
        let t = parse_csv(text, "azure").unwrap();
        let s: Vec<_> = t.requests.iter().map(|r| r.session.unwrap()).collect();
        assert_eq!(s[0].prefix_tokens, 0);
        assert_eq!(s[1].prefix_tokens, 120); // 100 + 20
        assert_eq!(s[2].prefix_tokens, 0); // new conversation
        assert_eq!(s[3].prefix_tokens, 170); // 140 + 30
        assert_eq!(s[0].id, s[1].id);
        assert_eq!(s[1].id, s[3].id);
        assert_ne!(s[0].id, s[2].id);
        // Derived prefixes are clamped to the prompt by with_session.
        for r in &t.requests {
            assert!(r.session.unwrap().prefix_tokens <= r.input_tokens);
        }
    }

    #[test]
    fn explicit_prefix_column_wins_over_derivation() {
        let text = "arrival_s,input_tokens,output_tokens,session_id,prefix_tokens\n\
                    0.0,100,20,7,0\n\
                    5.0,200,30,7,90\n\
                    8.0,60,10,,\n";
        let t = parse_csv(text, "x").unwrap();
        assert_eq!(t.requests[1].session.unwrap().prefix_tokens, 90);
        assert!(t.requests[2].session.is_none(), "empty cells mean sessionless");
    }

    #[test]
    fn jsonl_metadata_record_sets_duration() {
        let text = "{\"duration_s\": 100}\n{\"arrival_s\":1.5,\"input_tokens\":10,\"output_tokens\":2}\n";
        let t = parse_jsonl(text, "x").unwrap();
        assert_eq!(t.duration_s, 100.0);
        assert_eq!(t.requests.len(), 1);
    }
}
