//! Multi-turn conversational sessions ([`SessionSource`]).
//!
//! When a [`TraceSpec`] carries a [`SessionModel`], base arrivals become
//! session *openers* and the wrapper spawns follow-up turns: turn k+1's
//! prompt re-submits the whole conversation so far (prefix = Σ earlier
//! input + output tokens) plus a freshly sampled user message. The prefix
//! is exactly what a warm KV cache (`sim::kvcache`) can skip, so these
//! workloads are where cache-aware routing pays off.
//!
//! Determinism contract: one wrapper-owned [`Pcg64`] stream, drawn from in
//! *emission order* (turn count at the opener, fresh lengths + think gap
//! at each follow-up), so the stream is reproducible per seed and
//! identical whether drained eagerly or pulled lazily. The base source's
//! own streams are untouched — a spec with `sessions: None` never
//! constructs a wrapper and stays bit-identical to the historical output.

use super::source::{ArrivalSource, TraceProfile};
use super::spec::{LenDist, SessionModel, TraceSpec};
use crate::util::rng::Pcg64;
use crate::workload::Request;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Hard cap on turns per session: keeps a pathological geometric draw from
/// spawning unbounded context growth (the context cap would clamp it
/// anyway, but bounding the turn count also bounds per-session work).
const MAX_TURNS: u32 = 32;

/// A follow-up turn waiting for its arrival time, ordered for a min-heap
/// on `(time, seq)` — `seq` is an emission-order tie-break so equal times
/// pop deterministically.
struct PendingTurn {
    time: f64,
    seq: u64,
    session: u64,
    /// Accumulated conversation tokens (Σ prior input + output).
    prefix: usize,
    /// Turns still to come *after* this one.
    turns_left: u32,
}

impl PartialEq for PendingTurn {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for PendingTurn {}
impl PartialOrd for PendingTurn {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for PendingTurn {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .time
            .total_cmp(&self.time)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Session-structure wrapper over any arrival source (in practice the
/// synthetic [`super::SpecSource`] family). Base arrivals open sessions;
/// follow-up turns are spawned with exponential think-time gaps and
/// growing context prefixes, merged time-sorted, ids re-sequenced in
/// emission order.
pub struct SessionSource<S> {
    base: S,
    model: SessionModel,
    input_len: LenDist,
    output_len: LenDist,
    rng: Pcg64,
    pending: BinaryHeap<PendingTurn>,
    base_peek: Option<Request>,
    base_primed: bool,
    next_id: u64,
    next_session: u64,
    next_seq: u64,
}

impl<S: ArrivalSource> SessionSource<S> {
    /// Wrap `base` with the session structure of `spec` (which must carry
    /// `sessions: Some(..)`; the spec's length distributions sample the
    /// fresh per-turn user messages). `seed` should be the trace seed —
    /// the wrapper derives its own independent stream from it.
    pub fn new(spec: &TraceSpec, base: S, seed: u64) -> SessionSource<S> {
        let model = spec
            .sessions
            .expect("SessionSource requires a spec with a session model");
        SessionSource {
            base,
            model,
            input_len: spec.input_len,
            output_len: spec.output_len,
            // XOR-derived stream: independent of the base source's
            // `Pcg64::new(seed)` fork parent.
            rng: Pcg64::new(seed ^ 0x5E55_1045_CAFE_F00D),
            pending: BinaryHeap::new(),
            base_peek: None,
            base_primed: false,
            next_id: 0,
            next_session: 0,
            next_seq: 0,
        }
    }

    /// Probability each turn is followed by another, chosen so the mean
    /// turn count is `turns_mean` (geometric, min 1).
    fn continue_prob(&self) -> f64 {
        let m = self.model.turns_mean.max(1.0);
        (1.0 - 1.0 / m).clamp(0.0, 0.98)
    }

    /// Draw this session's total turn count (min 1, capped).
    fn draw_turns(&mut self) -> u32 {
        let p = self.continue_prob();
        let mut turns = 1u32;
        while turns < MAX_TURNS && self.rng.chance(p) {
            turns += 1;
        }
        turns
    }

    /// Schedule the next turn of a session, unless it would land past the
    /// stream horizon (truncated sessions simply end early).
    fn schedule_turn(&mut self, time: f64, session: u64, prefix: usize, turns_left: u32) {
        let gap = self.rng.exponential(1.0 / self.model.think_time_s.max(1e-6));
        let t = time + gap;
        if t >= self.base.duration_s() {
            return;
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending.push(PendingTurn {
            time: t,
            seq,
            session,
            prefix,
            turns_left,
        });
    }

    /// Emit a follow-up turn: fresh user message sampled from the spec
    /// length distributions, prompt = prefix + fresh, context clamped.
    fn emit_turn(&mut self, turn: PendingTurn) -> Request {
        let fresh = sample_len(&mut self.rng, &self.input_len);
        let output = sample_len(&mut self.rng, &self.output_len);
        // Clamp so prefix + fresh + output fits the context cap: the
        // oldest context is dropped first (prefix shrinks), keeping the
        // turn admissible on any decoder.
        let cap = self.model.max_context;
        let prefix = turn.prefix.min(cap.saturating_sub(fresh + output));
        let input = prefix + fresh;
        let next_prefix = input + output;
        if turn.turns_left > 0 {
            self.schedule_turn(turn.time, turn.session, next_prefix, turn.turns_left - 1);
        }
        let id = self.next_id;
        self.next_id += 1;
        Request::new(id, turn.time, input, output).with_session(turn.session, prefix)
    }

    /// Emit a session opener from a base arrival (turn 1, cold prefix).
    fn emit_opener(&mut self, base: Request) -> Request {
        let session = self.next_session;
        self.next_session += 1;
        let turns = self.draw_turns();
        if turns > 1 {
            let next_prefix = base.input_tokens + base.output_tokens;
            self.schedule_turn(base.arrival, session, next_prefix, turns - 2);
        }
        let id = self.next_id;
        self.next_id += 1;
        Request::new(id, base.arrival, base.input_tokens, base.output_tokens)
            .with_session(session, 0)
    }
}

fn sample_len(rng: &mut Pcg64, d: &LenDist) -> usize {
    (rng.lognormal(d.mu, d.sigma).round() as usize).clamp(d.min, d.max)
}

impl<S: ArrivalSource> ArrivalSource for SessionSource<S> {
    fn next_request(&mut self) -> Option<Request> {
        if !self.base_primed {
            self.base_peek = self.base.next_request();
            self.base_primed = true;
        }
        let take_pending = match (&self.base_peek, self.pending.peek()) {
            (None, Some(_)) => true,
            (Some(_), None) => false,
            (None, None) => return None,
            // Tie → opener first (matches the merge order of emission:
            // the opener was generated earlier).
            (Some(b), Some(p)) => p.time < b.arrival,
        };
        if take_pending {
            let turn = self.pending.pop().unwrap();
            Some(self.emit_turn(turn))
        } else {
            let base = self.base_peek.take().unwrap();
            self.base_peek = self.base.next_request();
            Some(self.emit_opener(base))
        }
    }

    fn duration_s(&self) -> f64 {
        self.base.duration_s()
    }

    fn label(&self) -> String {
        format!("{}+sessions", self.base.label())
    }

    fn profile(&self) -> TraceProfile {
        // Analytic estimate: openers arrive at the base rate and each
        // session averages `turns_mean` turns, so the request rate scales
        // by ~turns_mean (horizon truncation makes this an upper bound).
        // Turn k's prompt adds (k-1)·(input+output) of context; averaging
        // over k = 1..m gives + (m-1)/2 · (input+output), clamped to the
        // context cap.
        let base = self.base.profile();
        let m = self.model.turns_mean.max(1.0);
        let per_turn = base.avg_input_tokens + base.avg_output_tokens;
        let avg_input = (base.avg_input_tokens + (m - 1.0) / 2.0 * per_turn)
            .min(self.model.max_context as f64);
        TraceProfile {
            avg_rps: base.avg_rps * m,
            avg_input_tokens: avg_input,
            avg_output_tokens: base.avg_output_tokens,
            duration_s: base.duration_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::gen::SpecSource;
    use crate::trace::source::materialize;
    use crate::trace::spec::TraceFamily;

    fn sessioned_spec(rps: f64, dur: f64) -> TraceSpec {
        TraceFamily::AzureConv
            .spec(rps, dur)
            .with_sessions(SessionModel::new(3.0, 5.0))
    }

    fn build(rps: f64, dur: f64, seed: u64) -> SessionSource<SpecSource> {
        let spec = sessioned_spec(rps, dur);
        let base = SpecSource::new(spec.clone(), seed);
        SessionSource::new(&spec, base, seed)
    }

    #[test]
    fn sessions_are_deterministic_and_sorted() {
        let a = materialize(&mut build(6.0, 120.0, 7));
        let b = materialize(&mut build(6.0, 120.0, 7));
        assert_eq!(a.requests, b.requests);
        assert!(!a.requests.is_empty());
        for w in a.requests.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        for (i, r) in a.requests.iter().enumerate() {
            assert_eq!(r.id, i as u64, "ids re-sequenced in emission order");
        }
    }

    #[test]
    fn every_request_carries_a_session_and_valid_prefix() {
        let t = materialize(&mut build(6.0, 120.0, 11));
        let mut multi_turn = 0usize;
        for r in &t.requests {
            let s = r.session.expect("session workloads tag every request");
            assert!(s.prefix_tokens < r.input_tokens.max(1) + 1);
            assert!(s.prefix_tokens <= r.input_tokens);
            if s.prefix_tokens > 0 {
                multi_turn += 1;
            }
        }
        assert!(multi_turn > 0, "mean 3 turns must produce follow-ups");
    }

    #[test]
    fn turn_prefixes_grow_within_a_session() {
        let t = materialize(&mut build(4.0, 180.0, 3));
        use std::collections::HashMap;
        let mut last_prefix: HashMap<u64, usize> = HashMap::new();
        let mut turns_per: HashMap<u64, usize> = HashMap::new();
        for r in &t.requests {
            let s = r.session.unwrap();
            *turns_per.entry(s.id).or_insert(0) += 1;
            let prev = last_prefix.insert(s.id, s.prefix_tokens);
            if let Some(prev) = prev {
                // Prefix grows monotonically (clamping only ever lowers
                // it toward the cap, which itself grows with the turn).
                assert!(
                    s.prefix_tokens >= prev.min(s.prefix_tokens),
                    "session {} shrank below floor",
                    s.id
                );
                assert!(s.prefix_tokens > 0, "follow-up turns have warm prefixes");
            }
        }
        assert!(
            turns_per.values().any(|&n| n >= 2),
            "some session must have multiple turns"
        );
    }

    #[test]
    fn context_cap_bounds_every_turn() {
        let spec = TraceFamily::AzureConv.spec(6.0, 240.0).with_sessions(SessionModel {
            turns_mean: 6.0,
            think_time_s: 2.0,
            max_context: 4096,
        });
        let base = SpecSource::new(spec.clone(), 5);
        let t = materialize(&mut SessionSource::new(&spec, base, 5));
        for r in &t.requests {
            let s = r.session.unwrap();
            // Fresh (uncached) prompt + output can exceed the cap only
            // through a single oversized base sample; the *prefix* never
            // pushes past it.
            assert!(
                s.prefix_tokens + (r.input_tokens - s.prefix_tokens) + r.output_tokens
                    <= 4096 + 8192 + 1024,
                "prefix clamp failed"
            );
            if s.prefix_tokens > 0 {
                assert!(s.prefix_tokens + r.output_tokens <= 4096 + 1024);
            }
        }
    }

    #[test]
    fn sessionless_spec_is_untouched() {
        let spec = TraceFamily::AzureConv.spec(6.0, 60.0);
        assert!(spec.sessions.is_none());
        let t = materialize(&mut SpecSource::new(spec, 9));
        assert!(t.requests.iter().all(|r| r.session.is_none()));
    }
}
