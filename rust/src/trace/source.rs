//! Streaming arrival sources.
//!
//! The workload path used to be "materialize a `Vec<Request>`, then
//! simulate". [`ArrivalSource`] decouples generation from consumption: a
//! source is a pull-based, time-ordered request stream, deterministic per
//! seed, that the simulator drains one arrival at a time. Multi-hour
//! traces no longer live in memory per grid cell, external trace files
//! can be replayed (see [`super::replay`]), and transform combinators
//! (see [`super::transform`]) compose over any source.

use super::gen::Trace;
use crate::workload::Request;
use std::sync::Arc;

/// A-priori summary of a workload's character: what the experiment
/// harness needs *before* a run (velocity profiles, baseline threshold
/// derivations) without scanning a materialized request vector.
///
/// For materialized traces the profile is measured exactly; for synthetic
/// spec sources it is analytic (spec rate, length-distribution means);
/// combinators adjust it approximately and document how.
#[derive(Clone, Copy, Debug)]
pub struct TraceProfile {
    /// Expected average request rate over the stream (req/s).
    pub avg_rps: f64,
    /// Expected mean prompt length (tokens).
    pub avg_input_tokens: f64,
    /// Expected mean output length (tokens).
    pub avg_output_tokens: f64,
    /// Nominal stream duration (seconds).
    pub duration_s: f64,
}

impl TraceProfile {
    /// Measure a materialized trace exactly (the pre-streaming behavior:
    /// the same floats `Trace::avg_*` used to produce).
    pub fn of_trace(trace: &Trace) -> TraceProfile {
        TraceProfile {
            avg_rps: trace.avg_rps(),
            avg_input_tokens: trace.avg_input_tokens(),
            avg_output_tokens: trace.avg_output_tokens(),
            duration_s: trace.duration_s,
        }
    }
}

/// A pull-based, time-ordered arrival stream.
///
/// Contract: `next_request` yields requests with non-decreasing `arrival`
/// times and returns `None` once exhausted; for a given construction
/// (spec × seed × combinator chain) the sequence is deterministic.
pub trait ArrivalSource {
    /// Pull the next arrival, or `None` when the stream is exhausted.
    fn next_request(&mut self) -> Option<Request>;

    /// Nominal duration of the stream in seconds (the simulation horizon
    /// base; arrivals never exceed it).
    fn duration_s(&self) -> f64;

    /// Human-readable name for reporting.
    fn label(&self) -> String;

    /// A-priori workload estimate (see [`TraceProfile`]).
    fn profile(&self) -> TraceProfile;
}

impl<S: ArrivalSource + ?Sized> ArrivalSource for Box<S> {
    fn next_request(&mut self) -> Option<Request> {
        (**self).next_request()
    }
    fn duration_s(&self) -> f64 {
        (**self).duration_s()
    }
    fn label(&self) -> String {
        (**self).label()
    }
    fn profile(&self) -> TraceProfile {
        (**self).profile()
    }
}

/// Replay an already-materialized trace as a stream, generic over how the
/// trace is held. [`TraceSliceSource`] (borrowed) is the compatibility
/// bridge — `simulate(cfg, …, &Trace)` wraps the trace in one and drives
/// the streaming engine; [`OwnedTraceSource`] (owned) is what replay-file
/// factories hand each grid worker.
pub struct TraceReplaySource<T> {
    trace: T,
    idx: usize,
}

/// Borrowed replay of a materialized trace.
pub type TraceSliceSource<'t> = TraceReplaySource<&'t Trace>;

/// Owned replay of a materialized trace (e.g. one loaded from a file).
pub type OwnedTraceSource = TraceReplaySource<Trace>;

impl<T: std::borrow::Borrow<Trace>> TraceReplaySource<T> {
    pub fn new(trace: T) -> TraceReplaySource<T> {
        TraceReplaySource { trace, idx: 0 }
    }

    /// The underlying trace (e.g. for burst analytics on a loaded file).
    pub fn trace(&self) -> &Trace {
        self.trace.borrow()
    }
}

impl<T: std::borrow::Borrow<Trace>> ArrivalSource for TraceReplaySource<T> {
    fn next_request(&mut self) -> Option<Request> {
        let r = self.trace.borrow().requests.get(self.idx)?.clone();
        self.idx += 1;
        Some(r)
    }

    fn duration_s(&self) -> f64 {
        self.trace.borrow().duration_s
    }

    fn label(&self) -> String {
        self.trace.borrow().name.clone()
    }

    fn profile(&self) -> TraceProfile {
        TraceProfile::of_trace(self.trace.borrow())
    }
}

/// Drain a source into a materialized [`Trace`] — the oracle helper the
/// streaming/materialized equivalence tests compare against, and the
/// bridge for consumers that genuinely need the whole vector (burst
/// analytics, replay export).
pub fn materialize(src: &mut dyn ArrivalSource) -> Trace {
    let mut requests = Vec::new();
    while let Some(r) = src.next_request() {
        requests.push(r);
    }
    Trace {
        name: src.label(),
        duration_s: src.duration_s(),
        requests,
    }
}

/// Skip the first `n` arrivals of a freshly built source — the stream
/// resume primitive of the checkpoint subsystem (`sim::snapshot`).
///
/// Sources are deterministic per construction (spec × seed × transform
/// chain), so a snapshot records only how many arrivals were pulled;
/// resuming rebuilds the source identically and fast-forwards it, after
/// which the remaining stream is the exact suffix the interrupted run
/// would have consumed (property-tested in
/// `rust/tests/snapshot_equivalence.rs`). Returns the number actually
/// skipped (less than `n` only if the stream is shorter, which a
/// consistent snapshot never hits).
pub fn fast_forward(src: &mut dyn ArrivalSource, n: u64) -> u64 {
    for k in 0..n {
        if src.next_request().is_none() {
            return k;
        }
    }
    n
}

/// A shareable constructor of independent source instances: the grid
/// runner clones the factory into each worker so every (deployment ×
/// policy × seed) cell streams its own copy instead of sharing one
/// materialized vector.
pub type SourceFactory = Arc<dyn Fn() -> Box<dyn ArrivalSource + Send> + Send + Sync>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::gen::step_trace;

    #[test]
    fn slice_source_streams_all_requests_in_order() {
        let trace = step_trace(4.0, 4.0, 0.0, 0.0, 20.0, 128, 16, 1);
        let mut src = TraceSliceSource::new(&trace);
        let back = materialize(&mut src);
        assert_eq!(back.requests, trace.requests);
        assert_eq!(back.duration_s, trace.duration_s);
        assert_eq!(back.name, trace.name);
    }

    #[test]
    fn owned_source_matches_slice_source() {
        let trace = step_trace(3.0, 3.0, 0.0, 0.0, 15.0, 64, 8, 2);
        let a = materialize(&mut TraceSliceSource::new(&trace));
        let b = materialize(&mut OwnedTraceSource::new(trace.clone()));
        assert_eq!(a.requests, b.requests);
    }

    #[test]
    fn profile_of_trace_matches_avg_methods() {
        let trace = step_trace(5.0, 5.0, 0.0, 0.0, 30.0, 256, 32, 3);
        let p = TraceProfile::of_trace(&trace);
        assert_eq!(p.avg_rps, trace.avg_rps());
        assert_eq!(p.avg_input_tokens, trace.avg_input_tokens());
        assert_eq!(p.avg_output_tokens, trace.avg_output_tokens());
        assert_eq!(p.duration_s, trace.duration_s);
    }

    #[test]
    fn fast_forward_skips_exactly_n() {
        let trace = step_trace(4.0, 4.0, 0.0, 0.0, 20.0, 128, 16, 5);
        let n = trace.requests.len() as u64;
        let mut a = TraceSliceSource::new(&trace);
        assert_eq!(fast_forward(&mut a, 3), 3);
        assert_eq!(a.next_request().unwrap(), trace.requests[3]);
        // Over-running the stream reports the true skip count.
        let mut b = TraceSliceSource::new(&trace);
        assert_eq!(fast_forward(&mut b, n + 10), n);
        assert!(b.next_request().is_none());
    }

    #[test]
    fn boxed_source_delegates() {
        let trace = step_trace(2.0, 2.0, 0.0, 0.0, 10.0, 32, 4, 4);
        let n = trace.requests.len();
        let mut boxed: Box<dyn ArrivalSource + Send> = Box::new(OwnedTraceSource::new(trace));
        let mut count = 0;
        while boxed.next_request().is_some() {
            count += 1;
        }
        assert_eq!(count, n);
        assert_eq!(boxed.duration_s(), 10.0);
    }
}
