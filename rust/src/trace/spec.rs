//! Trace family specifications.
//!
//! Production traces (Azure LLM inference, BurstGPT) are not distributable
//! here, so each family is modeled as a Markov-modulated arrival process
//! (stable ↔ burst episodes) with family-specific token-length
//! distributions, parameterized to reproduce the paper's published
//! characteristics: bursts during ~47 % of operating time with ~2.3 s mean
//! episodes (§I), heavy-tailed lengths, ~22 RPS after sampling (§V).

/// Token length distribution: lognormal clipped to [min, max].
#[derive(Clone, Copy, Debug)]
pub struct LenDist {
    pub mu: f64,
    pub sigma: f64,
    pub min: usize,
    pub max: usize,
}

impl LenDist {
    pub fn new(mu: f64, sigma: f64, min: usize, max: usize) -> LenDist {
        LenDist { mu, sigma, min, max }
    }

    /// Approximate mean of the clipped lognormal (unclipped formula,
    /// adequate for capacity estimates).
    pub fn mean(&self) -> f64 {
        (self.mu + self.sigma * self.sigma / 2.0)
            .exp()
            .clamp(self.min as f64, self.max as f64)
    }
}

/// Burst-episode model: a two-state process. In the stable state arrivals
/// follow a Gamma renewal process at `base` rate; burst episodes multiply
/// the rate by `rate_factor` and last Exp(`mean_len_s`).
#[derive(Clone, Copy, Debug)]
pub struct BurstModel {
    /// Fraction of wall-clock time spent inside burst episodes.
    pub time_fraction: f64,
    /// Mean burst episode length, seconds.
    pub mean_len_s: f64,
    /// Arrival-rate multiplier during an episode.
    pub rate_factor: f64,
}

/// Multi-turn conversational-session model (`sim::kvcache` workloads).
///
/// When a [`TraceSpec`] carries one, base arrivals become session
/// *openers*: each opener draws a geometric turn count (mean
/// `turns_mean`, min 1) and spawns follow-up turns after exponential
/// think-time gaps. Turn k's prompt accumulates the full prior
/// conversation — prefix = Σ earlier (input + output) tokens — which is
/// exactly what a warm prefix cache can skip.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SessionModel {
    /// Mean turns per session (geometric; min 1 turn = the opener).
    pub turns_mean: f64,
    /// Mean think time between a turn's completion estimate and the next
    /// turn's arrival, seconds (exponential).
    pub think_time_s: f64,
    /// Conversation context cap: prefix + fresh input + output is clamped
    /// to this many tokens so late turns stay admissible on decoders.
    pub max_context: usize,
}

impl SessionModel {
    pub fn new(turns_mean: f64, think_time_s: f64) -> SessionModel {
        SessionModel {
            turns_mean,
            think_time_s,
            max_context: 16_384,
        }
    }
}

/// Complete description of a synthetic trace family.
#[derive(Clone, Debug)]
pub struct TraceSpec {
    pub name: String,
    /// Average request rate (requests/second) over the whole trace.
    pub rps: f64,
    /// Trace duration in seconds.
    pub duration_s: f64,
    /// Gamma shape for stable-state inter-arrivals; shape < 1 ⇒ CV > 1
    /// (burstier than Poisson even between episodes).
    pub arrival_shape: f64,
    pub input_len: LenDist,
    pub output_len: LenDist,
    pub burst: BurstModel,
    /// Amplitude of the slow sinusoidal load modulation (0 = flat), giving
    /// the running-average structure visible in the paper's Fig. 2.
    pub diurnal_amplitude: f64,
    /// Period of the slow modulation, seconds.
    pub diurnal_period_s: f64,
    /// Multi-turn session structure; `None` (every family default) keeps
    /// the historical single-shot arrivals bit-identically.
    pub sessions: Option<SessionModel>,
}

impl TraceSpec {
    /// Attach a session model (builder-style, for scenario/test setup).
    pub fn with_sessions(mut self, sessions: SessionModel) -> TraceSpec {
        self.sessions = Some(sessions);
        self
    }
}

/// The four production trace families the paper evaluates (§II-C1, §V),
/// plus the derived Mixed workload.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TraceFamily {
    AzureConv,
    AzureCode,
    BurstGpt1,
    BurstGpt2,
    Mixed,
}

impl TraceFamily {
    pub fn name(self) -> &'static str {
        match self {
            TraceFamily::AzureConv => "azure-conv",
            TraceFamily::AzureCode => "azure-code",
            TraceFamily::BurstGpt1 => "burstgpt-1",
            TraceFamily::BurstGpt2 => "burstgpt-2",
            TraceFamily::Mixed => "mixed",
        }
    }

    pub fn parse(s: &str) -> Option<TraceFamily> {
        match s.to_ascii_lowercase().as_str() {
            "azure-conv" | "azureconv" | "conv" => Some(TraceFamily::AzureConv),
            "azure-code" | "azurecode" | "code" => Some(TraceFamily::AzureCode),
            "burstgpt-1" | "burstgpt1" => Some(TraceFamily::BurstGpt1),
            "burstgpt-2" | "burstgpt2" => Some(TraceFamily::BurstGpt2),
            "mixed" => Some(TraceFamily::Mixed),
            _ => None,
        }
    }

    /// The trace spec at a given average RPS and duration.
    pub fn spec(self, rps: f64, duration_s: f64) -> TraceSpec {
        match self {
            // Conversation: medium prompts, medium-long outputs, bursts
            // ~47 % of time averaging 2.3 s (the paper's Azure analysis).
            TraceFamily::AzureConv => TraceSpec {
                name: self.name().into(),
                rps,
                duration_s,
                arrival_shape: 0.55,
                input_len: LenDist::new(6.2, 1.0, 8, 8192), // mean ~812
                output_len: LenDist::new(5.3, 0.8, 1, 1024), // mean ~275
                burst: BurstModel {
                    time_fraction: 0.47,
                    mean_len_s: 2.3,
                    rate_factor: 2.6,
                },
                diurnal_amplitude: 0.25,
                diurnal_period_s: 900.0,
                sessions: None,
            },
            // Code: long prompts, short outputs, sharper bursts.
            TraceFamily::AzureCode => TraceSpec {
                name: self.name().into(),
                rps,
                duration_s,
                arrival_shape: 0.45,
                input_len: LenDist::new(7.4, 0.9, 32, 8192), // mean ~2450
                output_len: LenDist::new(3.9, 0.7, 1, 512),  // mean ~63
                burst: BurstModel {
                    time_fraction: 0.40,
                    mean_len_s: 2.0,
                    rate_factor: 3.0,
                },
                diurnal_amplitude: 0.30,
                diurnal_period_s: 700.0,
                sessions: None,
            },
            // BurstGPT 1: GPT-conversation style — rarer but much taller
            // spikes than the Azure traces.
            TraceFamily::BurstGpt1 => TraceSpec {
                name: self.name().into(),
                rps,
                duration_s,
                arrival_shape: 0.35,
                input_len: LenDist::new(5.8, 1.1, 4, 8192), // mean ~605
                output_len: LenDist::new(5.6, 0.9, 1, 1024), // mean ~405
                burst: BurstModel {
                    time_fraction: 0.18,
                    mean_len_s: 3.0,
                    rate_factor: 8.0,
                },
                diurnal_amplitude: 0.35,
                diurnal_period_s: 600.0,
                sessions: None,
            },
            // BurstGPT 2: API-style, the burstiest of the four — calibrated
            // so ~25 % of requests exceed a 3×-overprovisioned trendline
            // (the paper's Fig. 3a headline).
            TraceFamily::BurstGpt2 => TraceSpec {
                name: self.name().into(),
                rps,
                duration_s,
                arrival_shape: 0.30,
                input_len: LenDist::new(6.0, 1.2, 4, 8192), // mean ~830
                output_len: LenDist::new(5.0, 1.0, 1, 1024), // mean ~245
                burst: BurstModel {
                    time_fraction: 0.12,
                    mean_len_s: 2.5,
                    rate_factor: 12.0,
                },
                diurnal_amplitude: 0.40,
                diurnal_period_s: 500.0,
                sessions: None,
            },
            // Mixed is generated by interleaving the other four at equal
            // rates (see `generate_mixed`); the spec here only carries the
            // aggregate rate for reporting.
            TraceFamily::Mixed => TraceSpec {
                name: self.name().into(),
                rps,
                duration_s,
                arrival_shape: 0.45,
                input_len: LenDist::new(6.3, 1.1, 4, 8192),
                output_len: LenDist::new(5.2, 0.9, 1, 1024),
                burst: BurstModel {
                    time_fraction: 0.40,
                    mean_len_s: 2.4,
                    rate_factor: 3.5,
                },
                diurnal_amplitude: 0.30,
                diurnal_period_s: 650.0,
                sessions: None,
            },
        }
    }
}

/// All four base (non-mixed) families, in the paper's Fig. 3 order.
pub fn base_families() -> Vec<TraceFamily> {
    vec![
        TraceFamily::AzureConv,
        TraceFamily::AzureCode,
        TraceFamily::BurstGpt1,
        TraceFamily::BurstGpt2,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names_roundtrip() {
        for f in base_families().into_iter().chain([TraceFamily::Mixed]) {
            assert_eq!(TraceFamily::parse(f.name()), Some(f));
        }
        assert_eq!(TraceFamily::parse("nope"), None);
    }

    #[test]
    fn lendist_mean_sane() {
        let d = LenDist::new(6.2, 1.0, 8, 8192);
        let m = d.mean();
        assert!((500.0..1500.0).contains(&m), "mean={m}");
    }

    #[test]
    fn azure_conv_burst_params_match_paper() {
        let s = TraceFamily::AzureConv.spec(22.0, 60.0);
        assert!((s.burst.time_fraction - 0.47).abs() < 1e-9);
        assert!((s.burst.mean_len_s - 2.3).abs() < 1e-9);
    }

    #[test]
    fn code_has_longer_inputs_shorter_outputs_than_conv() {
        let conv = TraceFamily::AzureConv.spec(22.0, 60.0);
        let code = TraceFamily::AzureCode.spec(22.0, 60.0);
        assert!(code.input_len.mean() > conv.input_len.mean());
        assert!(code.output_len.mean() < conv.output_len.mean());
    }
}
