//! Transform combinators over arrival sources.
//!
//! Each combinator wraps any [`ArrivalSource`] and is itself a source, so
//! chains compose: replay an Azure-style file, splice out an hour, scale
//! it to a target RPS, overlay a diurnal sinusoid and inject bursts — all
//! lazily, deterministic per seed, without materializing intermediates.
//!
//! Ordering guarantee: every combinator preserves non-decreasing arrival
//! times. The duplication-based ones (resample, burst injection) jitter
//! copies by up to [`MAX_JITTER_S`] and therefore run a small reorder
//! buffer: a pending copy is only emitted once its timestamp is ≤ the
//! next upstream arrival, after which no earlier copy can appear.

use super::source::{ArrivalSource, TraceProfile};
use crate::util::rng::Pcg64;
use crate::workload::{Request, SessionRef};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Maximum jitter applied to duplicated arrivals (seconds).
pub const MAX_JITTER_S: f64 = 0.050;

/// A pending duplicated arrival inside a reorder buffer, min-ordered by
/// (time, insertion seq) so ties pop FIFO and deterministically.
#[derive(Clone, Debug)]
struct Pending {
    time: f64,
    seq: u64,
    input_tokens: usize,
    output_tokens: usize,
    session: Option<SessionRef>,
}

impl PartialEq for Pending {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Pending {}
impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Pending {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Shared machinery of the duplication-based combinators ([`Resample`],
/// [`BurstInject`]): pull upstream arrivals, expand each into a
/// probabilistic number of jittered copies, and emit from the reorder
/// buffer only once nothing earlier can still arrive (a buffered copy is
/// safe when its timestamp is ≤ the next upstream arrival).
struct DupEmitter {
    pending: BinaryHeap<Pending>,
    peeked: Option<Request>,
    primed: bool,
    seq: u64,
    next_id: u64,
}

impl DupEmitter {
    fn new() -> DupEmitter {
        DupEmitter {
            pending: BinaryHeap::new(),
            peeked: None,
            primed: false,
            seq: 0,
            next_id: 0,
        }
    }

    /// Emit the next request. `factor(r)` is the expected copy count for
    /// an upstream arrival (fractional part resolved by one Bernoulli
    /// draw); `min_copies` floors the result (1 ⇒ the original always
    /// passes through). Copies after the first are jittered by up to
    /// [`MAX_JITTER_S`] and clamped to the stream horizon.
    fn next(
        &mut self,
        inner: &mut dyn ArrivalSource,
        rng: &mut Pcg64,
        min_copies: usize,
        factor: impl Fn(&Request) -> f64,
    ) -> Option<Request> {
        if !self.primed {
            self.peeked = inner.next_request();
            self.primed = true;
        }
        loop {
            if let Some(p) = self.pending.peek() {
                let safe = match &self.peeked {
                    None => true,
                    Some(n) => p.time <= n.arrival,
                };
                if safe {
                    let p = self.pending.pop().unwrap();
                    let mut r = Request::new(self.next_id, p.time, p.input_tokens, p.output_tokens);
                    r.session = p.session;
                    self.next_id += 1;
                    return Some(r);
                }
            }
            let r = self.peeked.take()?;
            self.peeked = inner.next_request();
            let f = factor(&r);
            let mut copies = f.floor() as usize;
            if rng.f64() < f - f.floor() {
                copies += 1;
            }
            let duration = inner.duration_s();
            for c in 0..copies.max(min_copies) {
                let jitter = if c == 0 {
                    0.0
                } else {
                    rng.range_f64(0.0, MAX_JITTER_S)
                };
                self.pending.push(Pending {
                    time: (r.arrival + jitter).min(duration),
                    seq: self.seq,
                    input_tokens: r.input_tokens,
                    output_tokens: r.output_tokens,
                    // Every copy keeps the session ref: duplicated turns
                    // model the same user retrying, so the warm prefix
                    // still applies.
                    session: r.session,
                });
                self.seq += 1;
            }
        }
    }
}

// ------------------------------------------------------------- Window

/// Time-window splice: keep arrivals in `[t0, t1)`, shifted so the window
/// starts at 0. Ids are re-sequenced from 0.
pub struct Window<S> {
    inner: S,
    t0: f64,
    t1: f64,
    next_id: u64,
    done: bool,
}

impl<S: ArrivalSource> Window<S> {
    pub fn new(inner: S, t0: f64, t1: f64) -> Window<S> {
        assert!(t1 >= t0, "window end before start");
        // Clamp to the source's own horizon: a window reaching past it
        // would inflate the simulation horizon (and dilute every
        // horizon-averaged metric) with guaranteed-empty time.
        let t1 = t1.min(inner.duration_s()).max(t0);
        Window {
            inner,
            t0,
            t1,
            next_id: 0,
            done: false,
        }
    }
}

impl<S: ArrivalSource> ArrivalSource for Window<S> {
    fn next_request(&mut self) -> Option<Request> {
        if self.done {
            return None;
        }
        loop {
            let Some(r) = self.inner.next_request() else {
                self.done = true;
                return None;
            };
            if r.arrival < self.t0 {
                continue;
            }
            if r.arrival >= self.t1 {
                // Upstream is time-sorted: nothing later can fall back in.
                self.done = true;
                return None;
            }
            let mut req =
                Request::new(self.next_id, r.arrival - self.t0, r.input_tokens, r.output_tokens);
            req.session = r.session;
            self.next_id += 1;
            return Some(req);
        }
    }

    fn duration_s(&self) -> f64 {
        self.t1 - self.t0
    }

    fn label(&self) -> String {
        format!("{}[{}..{}s]", self.inner.label(), self.t0, self.t1)
    }

    fn profile(&self) -> TraceProfile {
        // Rate estimate carries over; only the horizon shrinks.
        TraceProfile {
            duration_s: self.t1 - self.t0,
            ..self.inner.profile()
        }
    }
}

// ---------------------------------------------------------- RateScale

/// Compress or stretch time by `factor`: arrivals at `t` move to
/// `t / factor`, so the request rate is multiplied by `factor` while the
/// per-request token lengths are untouched.
pub struct RateScale<S> {
    inner: S,
    factor: f64,
}

impl<S: ArrivalSource> RateScale<S> {
    pub fn new(inner: S, factor: f64) -> RateScale<S> {
        assert!(factor > 0.0, "rate factor must be positive");
        RateScale { inner, factor }
    }
}

impl<S: ArrivalSource> ArrivalSource for RateScale<S> {
    fn next_request(&mut self) -> Option<Request> {
        let mut r = self.inner.next_request()?;
        r.arrival /= self.factor;
        Some(r)
    }

    fn duration_s(&self) -> f64 {
        self.inner.duration_s() / self.factor
    }

    fn label(&self) -> String {
        format!("{}*{}x", self.inner.label(), self.factor)
    }

    fn profile(&self) -> TraceProfile {
        let p = self.inner.profile();
        TraceProfile {
            avg_rps: p.avg_rps * self.factor,
            duration_s: p.duration_s / self.factor,
            ..p
        }
    }
}

// ------------------------------------------------------------ Diurnal

/// Diurnal sinusoid modulation by probabilistic thinning: an arrival at
/// time `t` is kept with probability
/// `(1 + a·sin(2πt/T)) / (1 + a)`, so the shape follows the sinusoid and
/// the long-run rate is ≈ `1/(1+a)` of the source's. Deterministic per
/// seed; ids re-sequenced from 0.
pub struct Diurnal<S> {
    inner: S,
    amplitude: f64,
    period_s: f64,
    rng: Pcg64,
    next_id: u64,
}

impl<S: ArrivalSource> Diurnal<S> {
    pub fn new(inner: S, amplitude: f64, period_s: f64, seed: u64) -> Diurnal<S> {
        assert!(period_s > 0.0, "diurnal period must be positive");
        Diurnal {
            inner,
            amplitude: amplitude.clamp(0.0, 0.95),
            period_s,
            rng: Pcg64::new(seed),
            next_id: 0,
        }
    }
}

impl<S: ArrivalSource> ArrivalSource for Diurnal<S> {
    fn next_request(&mut self) -> Option<Request> {
        loop {
            let r = self.inner.next_request()?;
            let phase = 2.0 * std::f64::consts::PI * r.arrival / self.period_s;
            let keep = (1.0 + self.amplitude * phase.sin()) / (1.0 + self.amplitude);
            if self.rng.f64() < keep {
                let mut req = Request::new(self.next_id, r.arrival, r.input_tokens, r.output_tokens);
                req.session = r.session;
                self.next_id += 1;
                return Some(req);
            }
        }
    }

    fn duration_s(&self) -> f64 {
        self.inner.duration_s()
    }

    fn label(&self) -> String {
        format!("{}+diurnal", self.inner.label())
    }

    fn profile(&self) -> TraceProfile {
        let p = self.inner.profile();
        TraceProfile {
            // Mean keep probability over whole periods is 1/(1+a).
            avg_rps: p.avg_rps / (1.0 + self.amplitude),
            ..p
        }
    }
}

// -------------------------------------------------------- BurstInject

/// One injected burst episode: arrivals inside
/// `[start_s, start_s + len_s)` are duplicated so the local rate is
/// multiplied by `rate_factor`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BurstWindow {
    pub start_s: f64,
    pub len_s: f64,
    pub rate_factor: f64,
}

impl BurstWindow {
    pub fn new(start_s: f64, len_s: f64, rate_factor: f64) -> BurstWindow {
        BurstWindow {
            start_s,
            len_s,
            rate_factor,
        }
    }

    fn contains(&self, t: f64) -> bool {
        t >= self.start_s && t < self.start_s + self.len_s
    }
}

/// Burst injection: multiply the arrival rate inside each
/// [`BurstWindow`] by duplicating arrivals (copies carry the original
/// token lengths, jittered ≤ [`MAX_JITTER_S`]). Outside windows the
/// stream passes through untouched. Ids re-sequenced from 0.
pub struct BurstInject<S> {
    inner: S,
    bursts: Vec<BurstWindow>,
    rng: Pcg64,
    emit: DupEmitter,
}

impl<S: ArrivalSource> BurstInject<S> {
    pub fn new(inner: S, bursts: Vec<BurstWindow>, seed: u64) -> BurstInject<S> {
        for b in &bursts {
            assert!(b.len_s >= 0.0 && b.rate_factor >= 1.0, "bad burst window");
        }
        BurstInject {
            inner,
            bursts,
            rng: Pcg64::new(seed),
            emit: DupEmitter::new(),
        }
    }
}

impl<S: ArrivalSource> ArrivalSource for BurstInject<S> {
    fn next_request(&mut self) -> Option<Request> {
        let bursts = &self.bursts;
        // min_copies = 1: outside burst windows the stream passes through.
        self.emit.next(&mut self.inner, &mut self.rng, 1, |r| {
            bursts
                .iter()
                .find(|b| b.contains(r.arrival))
                .map(|b| b.rate_factor)
                .unwrap_or(1.0)
        })
    }

    fn duration_s(&self) -> f64 {
        self.inner.duration_s()
    }

    fn label(&self) -> String {
        format!("{}+bursts", self.inner.label())
    }

    fn profile(&self) -> TraceProfile {
        let p = self.inner.profile();
        let dur = p.duration_s.max(1e-9);
        let extra: f64 = self
            .bursts
            .iter()
            .map(|b| (b.rate_factor - 1.0) * (b.len_s / dur))
            .sum();
        TraceProfile {
            avg_rps: p.avg_rps * (1.0 + extra),
            ..p
        }
    }
}

// ----------------------------------------------------------- Resample

/// Resample to a target average RPS (the paper's §V sampling to 22 RPS):
/// uniform thinning when the target is below the source rate, duplication
/// with ≤ [`MAX_JITTER_S`] jitter when above. The keep/duplicate ratio is
/// derived from the source's [`TraceProfile::avg_rps`] estimate. Output
/// stays time-sorted (reorder buffer) and ids are re-sequenced from 0 in
/// emission order, deterministic for a given rng seed.
pub struct Resample<S> {
    inner: S,
    target_rps: f64,
    keep: f64,
    rng: Pcg64,
    emit: DupEmitter,
}

impl<S: ArrivalSource> Resample<S> {
    pub fn new(inner: S, target_rps: f64, rng: Pcg64) -> Resample<S> {
        let cur = inner.profile().avg_rps;
        let keep = if cur > 0.0 { target_rps / cur } else { 1.0 };
        Resample {
            inner,
            target_rps,
            keep,
            rng,
            emit: DupEmitter::new(),
        }
    }
}

impl<S: ArrivalSource> ArrivalSource for Resample<S> {
    fn next_request(&mut self) -> Option<Request> {
        let keep = self.keep;
        // min_copies = 0: thinning may drop an arrival entirely.
        self.emit.next(&mut self.inner, &mut self.rng, 0, |_| keep)
    }

    fn duration_s(&self) -> f64 {
        self.inner.duration_s()
    }

    fn label(&self) -> String {
        self.inner.label()
    }

    fn profile(&self) -> TraceProfile {
        TraceProfile {
            avg_rps: self.target_rps,
            ..self.inner.profile()
        }
    }
}

// ----------------------------------------------------------- SourceExt

/// Fluent combinator constructors for any source:
/// `SpecSource::new(spec, seed).window(0.0, 3600.0).diurnal(0.4, 3600.0, 7)`.
pub trait SourceExt: ArrivalSource + Sized {
    /// Splice out `[t0, t1)`, re-based to start at 0.
    fn window(self, t0: f64, t1: f64) -> Window<Self> {
        Window::new(self, t0, t1)
    }

    /// Compress time so the request rate is multiplied by `factor`.
    fn scale_rate(self, factor: f64) -> RateScale<Self> {
        RateScale::new(self, factor)
    }

    /// Overlay a sinusoidal diurnal pattern by thinning.
    fn diurnal(self, amplitude: f64, period_s: f64, seed: u64) -> Diurnal<Self> {
        Diurnal::new(self, amplitude, period_s, seed)
    }

    /// Inject burst episodes by local duplication.
    fn inject_bursts(self, bursts: Vec<BurstWindow>, seed: u64) -> BurstInject<Self> {
        BurstInject::new(self, bursts, seed)
    }

    /// Thin/duplicate to a target average RPS.
    fn resample_rps(self, target_rps: f64, seed: u64) -> Resample<Self> {
        Resample::new(self, target_rps, Pcg64::new(seed))
    }

    /// Box the chain for use behind a [`super::source::SourceFactory`].
    fn boxed(self) -> Box<dyn ArrivalSource + Send>
    where
        Self: Send + 'static,
    {
        Box::new(self)
    }

    /// Drain into a materialized [`super::gen::Trace`].
    fn collect_trace(mut self) -> super::gen::Trace {
        super::source::materialize(&mut self)
    }
}

impl<S: ArrivalSource + Sized> SourceExt for S {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::gen::{SpecSource, Trace};
    use crate::trace::source::{materialize, OwnedTraceSource};
    use crate::trace::spec::TraceFamily;

    fn sorted(t: &Trace) -> bool {
        t.requests.windows(2).all(|w| w[0].arrival <= w[1].arrival)
    }

    fn ids_sequential(t: &Trace) -> bool {
        t.requests.iter().enumerate().all(|(i, r)| r.id == i as u64)
    }

    fn base(seed: u64) -> SpecSource {
        SpecSource::new(TraceFamily::AzureConv.spec(10.0, 120.0), seed)
    }

    #[test]
    fn window_splices_and_rebases() {
        let full = base(1).collect_trace();
        let win = base(1).window(30.0, 90.0).collect_trace();
        assert_eq!(win.duration_s, 60.0);
        assert!(sorted(&win) && ids_sequential(&win));
        assert!(win.requests.iter().all(|r| r.arrival >= 0.0 && r.arrival < 60.0));
        let expect = full
            .requests
            .iter()
            .filter(|r| r.arrival >= 30.0 && r.arrival < 90.0)
            .count();
        assert_eq!(win.requests.len(), expect);
    }

    #[test]
    fn rate_scale_compresses_time() {
        let full = base(2).collect_trace();
        let fast = base(2).scale_rate(2.0).collect_trace();
        assert_eq!(fast.requests.len(), full.requests.len());
        assert_eq!(fast.duration_s, 60.0);
        assert!((fast.avg_rps() - 2.0 * full.avg_rps()).abs() < 1e-9);
        assert!(sorted(&fast));
    }

    #[test]
    fn diurnal_thins_and_stays_sorted() {
        let full = base(3).collect_trace();
        let mod_src = base(3).diurnal(0.5, 60.0, 99);
        assert!(mod_src.profile().avg_rps < 10.0);
        let t = mod_src.collect_trace();
        assert!(sorted(&t) && ids_sequential(&t));
        assert!(t.requests.len() < full.requests.len());
        assert!(t.requests.len() > full.requests.len() / 4);
    }

    #[test]
    fn burst_inject_adds_in_window_only() {
        let full = base(4).collect_trace();
        let t = base(4)
            .inject_bursts(vec![BurstWindow::new(40.0, 20.0, 3.0)], 7)
            .collect_trace();
        assert!(sorted(&t) && ids_sequential(&t));
        let in_win = |tr: &Trace| {
            tr.requests
                .iter()
                .filter(|r| r.arrival >= 40.0 && r.arrival < 20.0 + 40.0 + MAX_JITTER_S)
                .count()
        };
        let out_before = |tr: &Trace| tr.requests.iter().filter(|r| r.arrival < 40.0).count();
        assert!(in_win(&t) > in_win(&full) * 2, "{} vs {}", in_win(&t), in_win(&full));
        assert_eq!(out_before(&t), out_before(&full));
    }

    #[test]
    fn combinators_are_deterministic() {
        let a = base(5).diurnal(0.4, 90.0, 11).collect_trace();
        let b = base(5).diurnal(0.4, 90.0, 11).collect_trace();
        assert_eq!(a.requests, b.requests);
        let c = base(5)
            .inject_bursts(vec![BurstWindow::new(10.0, 30.0, 2.5)], 13)
            .collect_trace();
        let d = base(5)
            .inject_bursts(vec![BurstWindow::new(10.0, 30.0, 2.5)], 13)
            .collect_trace();
        assert_eq!(c.requests, d.requests);
    }

    #[test]
    fn resample_up_keeps_sorted_sequential_ids() {
        let trace = base(6).collect_trace();
        let up = OwnedTraceSource::new(trace.clone())
            .resample_rps(30.0, 17)
            .collect_trace();
        assert!(sorted(&up) && ids_sequential(&up));
        assert!((up.avg_rps() - 30.0).abs() < 4.0, "rps={}", up.avg_rps());
    }

    #[test]
    fn transforms_preserve_session_refs() {
        use crate::trace::gen::spec_source;
        use crate::trace::spec::SessionModel;
        let spec = TraceFamily::AzureConv
            .spec(10.0, 120.0)
            .with_sessions(SessionModel::new(3.0, 5.0));
        let full = materialize(&mut *spec_source(&spec, 42));
        assert!(full.requests.iter().any(|r| r.session.is_some()));
        let mut chained = spec_source(&spec, 42)
            .window(10.0, 110.0)
            .diurnal(0.3, 40.0, 9)
            .inject_bursts(vec![BurstWindow::new(30.0, 20.0, 2.0)], 10);
        let t = materialize(&mut chained);
        assert!(sorted(&t) && ids_sequential(&t));
        // Every surviving/duplicated arrival still carries its session ref
        // with a prefix no larger than its prompt.
        assert!(t.requests.iter().any(|r| r.session.is_some()));
        for r in &t.requests {
            if let Some(s) = r.session {
                assert!(s.prefix_tokens <= r.input_tokens);
            }
        }
    }

    #[test]
    fn chain_composes() {
        let mut chained = base(8)
            .window(0.0, 60.0)
            .diurnal(0.3, 30.0, 21)
            .inject_bursts(vec![BurstWindow::new(20.0, 10.0, 2.0)], 22);
        let t = materialize(&mut chained);
        assert!(sorted(&t) && ids_sequential(&t));
        assert!(!t.requests.is_empty());
        assert_eq!(t.duration_s, 60.0);
    }
}
