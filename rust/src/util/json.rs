//! Minimal JSON reader/writer.
//!
//! serde is not in the offline crate set, so configuration files, model
//! metadata from the AOT pipeline (`artifacts/model_meta.json`) and result
//! emission use this hand-rolled implementation. It supports the full JSON
//! data model minus exotic number forms, which is all we need.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Builder-style insert for object construction.
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), value.into());
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path lookup: `get_path(&["a","b"])` == obj["a"]["b"].
    pub fn get_path(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field helpers with contextual errors (config loading).
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/not-a-number field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/not-a-string field `{key}`"))
    }

    /// Parse JSON text.
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            anyhow::bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", *x as i64));
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(n) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(n * (depth + 1)));
                    }
                    item.write(out, indent, depth + 1);
                }
                if indent.is_some() && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(n) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(n * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- bit-exact scalar encodings (checkpoint/restore) -------------------
//
// JSON numbers cannot carry every value the simulator state holds: `f64`
// round-trips only for finite values (and the engine stores `INFINITY`
// sentinels), and `u64`/`u128` counters exceed the 2^53 exact-integer
// range. The snapshot subsystem therefore encodes them as fixed-width
// lowercase-hex *strings* of the underlying bits, which round-trip
// losslessly by construction.

impl Json {
    /// Encode an `f64` bit-exactly (hex of `to_bits`). Handles ±inf/NaN.
    pub fn f64_bits(x: f64) -> Json {
        Json::Str(format!("{:016x}", x.to_bits()))
    }

    /// Decode a [`Json::f64_bits`] value.
    pub fn as_f64_bits(&self) -> Option<f64> {
        let s = self.as_str()?;
        if s.len() != 16 {
            return None;
        }
        u64::from_str_radix(s, 16).ok().map(f64::from_bits)
    }

    /// Encode a `u64` bit-exactly as 16 hex digits.
    pub fn u64_hex(x: u64) -> Json {
        Json::Str(format!("{x:016x}"))
    }

    /// Decode a [`Json::u64_hex`] value.
    pub fn as_u64_hex(&self) -> Option<u64> {
        let s = self.as_str()?;
        if s.len() != 16 {
            return None;
        }
        u64::from_str_radix(s, 16).ok()
    }

    /// Encode a `u128` bit-exactly as 32 hex digits (PCG64 state words).
    pub fn u128_hex(x: u128) -> Json {
        Json::Str(format!("{x:032x}"))
    }

    /// Decode a [`Json::u128_hex`] value.
    pub fn as_u128_hex(&self) -> Option<u128> {
        let s = self.as_str()?;
        if s.len() != 32 {
            return None;
        }
        u128::from_str_radix(s, 16).ok()
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 codepoint
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!("expected , or ] found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                other => anyhow::bail!("expected , or }} found {:?}", other.map(|c| c as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj()
            .set("name", "tokenscale")
            .set("rps", 22.0)
            .set("enabled", true)
            .set("buckets", vec![1.0, 2.5, 3.0])
            .set("nested", Json::obj().set("x", Json::Null));
        let text = j.pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("0").unwrap(), Json::Num(0.0));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j, Json::Str("a\nb\t\"c\" A".into()));
    }

    #[test]
    fn escape_roundtrip() {
        let j = Json::Str("line1\nline2\t\"quoted\"\\".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn path_lookup() {
        let j = Json::parse(r#"{"a":{"b":{"c":42}}}"#).unwrap();
        assert_eq!(j.get_path(&["a", "b", "c"]).unwrap().as_f64(), Some(42.0));
        assert!(j.get_path(&["a", "z"]).is_none());
    }

    #[test]
    fn unicode_content() {
        let j = Json::parse(r#"{"s":"héllo → 世界"}"#).unwrap();
        assert_eq!(j.get("s").unwrap().as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn bit_exact_scalars_round_trip() {
        for x in [
            0.0,
            -0.0,
            1.5,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MIN_POSITIVE,
            1.0 / 3.0,
            -123.456789e-12,
        ] {
            let j = Json::f64_bits(x);
            let text = j.to_string();
            let back = Json::parse(&text).unwrap().as_f64_bits().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x}");
        }
        let n = Json::f64_bits(f64::NAN);
        assert!(Json::parse(&n.to_string()).unwrap().as_f64_bits().unwrap().is_nan());
        for x in [0u64, 1, u64::MAX, 1 << 63] {
            assert_eq!(Json::u64_hex(x).as_u64_hex(), Some(x));
        }
        for x in [0u128, u128::MAX, 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645] {
            assert_eq!(Json::u128_hex(x).as_u128_hex(), Some(x));
        }
        // Wrong widths are rejected, not misparsed.
        assert_eq!(Json::Str("abc".into()).as_f64_bits(), None);
        assert_eq!(Json::Str("abc".into()).as_u64_hex(), None);
        assert_eq!(Json::Num(1.0).as_u128_hex(), None);
    }
}
