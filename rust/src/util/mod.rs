//! Foundational utilities: deterministic RNG, JSON, statistics, table/CSV
//! rendering, and a hand-rolled property-testing harness.
//!
//! These replace crates (`rand`, `serde_json`, `proptest`, `criterion`
//! report helpers) that are unavailable in the offline build environment;
//! see DESIGN.md "Dependency substitutions".

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod toml;
