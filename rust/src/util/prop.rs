//! Hand-rolled property-based testing (proptest is unavailable offline).
//!
//! A property runs against `cases` randomly generated inputs drawn from a
//! caller-supplied generator. On failure the harness attempts a simple
//! "re-seed shrink": it replays the failing case and reports the seed so the
//! failure is reproducible. Generators get a forked [`Pcg64`] per case.
//!
//! ```no_run
//! // (no_run: rustdoc test binaries lack the xla rpath in this image)
//! use tokenscale::util::prop::{check, Config};
//! check(Config::named("sum-commutes"), |rng| {
//!     let a = rng.below(1000) as i64;
//!     let b = rng.below(1000) as i64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Pcg64;

/// Property-test configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Human-readable property name, included in failure messages.
    pub name: String,
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; each case forks a child generator from it.
    pub seed: u64,
}

impl Config {
    pub fn named(name: &str) -> Config {
        Config {
            name: name.to_string(),
            cases: default_cases(),
            seed: env_seed(),
        }
    }

    pub fn cases(mut self, n: usize) -> Config {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Config {
        self.seed = s;
        self
    }
}

fn default_cases() -> usize {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

fn env_seed() -> u64 {
    std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xA11CE5)
}

/// Run `property` against `cfg.cases` random inputs. Panics (failing the
/// surrounding `#[test]`) with the case seed on the first failing case.
pub fn check<F>(cfg: Config, mut property: F)
where
    F: FnMut(&mut Pcg64),
{
    let mut master = Pcg64::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = master.next_u64();
        let mut rng = Pcg64::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property `{}` failed on case {}/{} (replay with PROP_SEED per-case seed {}):\n{}",
                cfg.name, case + 1, cfg.cases, case_seed, msg
            );
        }
    }
}

/// Generate a random vector with length in [min_len, max_len] whose items
/// come from `gen`.
pub fn vec_of<T>(
    rng: &mut Pcg64,
    min_len: usize,
    max_len: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
) -> Vec<T> {
    let len = rng.range_usize(min_len, max_len);
    (0..len).map(|_| gen(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(Config::named("abs-nonneg").cases(64), |rng| {
            let x = rng.normal();
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check(Config::named("always-fails").cases(4), |_rng| {
                panic!("intentional");
            });
        });
        let msg = match result {
            Err(p) => p
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
            Ok(_) => panic!("property should have failed"),
        };
        assert!(msg.contains("always-fails"), "msg={msg}");
        assert!(msg.contains("replay"), "msg={msg}");
    }

    #[test]
    fn vec_of_respects_bounds() {
        let mut rng = Pcg64::new(3);
        for _ in 0..100 {
            let v = vec_of(&mut rng, 2, 5, |r| r.below(10));
            assert!((2..=5).contains(&v.len()));
        }
    }
}
