//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so we implement PCG64 (DXSM flavour)
//! seeded via SplitMix64. Every stochastic component in the library (trace
//! generators, predictor noise, property tests) takes an explicit [`Pcg64`]
//! so runs are reproducible from a single `u64` seed.

/// SplitMix64 step, used to expand a single `u64` seed into stream state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG64-DXSM generator: 128-bit LCG state, 64-bit output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MUL: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream derived from the seed).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm) as u128;
        let s1 = splitmix64(&mut sm) as u128;
        let i0 = splitmix64(&mut sm) as u128;
        let i1 = splitmix64(&mut sm) as u128;
        let mut rng = Pcg64 {
            state: (s0 << 64) | s1,
            inc: ((i0 << 64) | i1) | 1,
        };
        rng.next_u64();
        rng
    }

    /// Derive an independent child generator (for per-component streams).
    pub fn fork(&mut self) -> Pcg64 {
        Pcg64::new(self.next_u64())
    }

    /// The raw generator state `(state, inc)` — the exact position of the
    /// stream, for checkpoint/restore of stochastic components.
    pub fn state_parts(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator at an exact stream position captured by
    /// [`Pcg64::state_parts`]. The next output is bit-identical to what
    /// the captured generator would have produced.
    pub fn from_state_parts(state: u128, inc: u128) -> Pcg64 {
        Pcg64 { state, inc }
    }

    /// Next raw 64-bit output (DXSM output permutation).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let state = self.state;
        self.state = state.wrapping_mul(PCG_MUL).wrapping_add(self.inc);
        let mut hi = (state >> 64) as u64;
        let lo = (state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda94_2042_e4dd_58b5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform u64 in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi] inclusive.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple, fine for
    /// trace generation rates).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Gamma(shape k, scale theta) via Marsaglia–Tsang, with the k<1 boost.
    /// Used for bursty (CV > 1) inter-arrival processes, matching the
    /// Gamma-arrival model production LLM traces are commonly fit with.
    pub fn gamma(&mut self, k: f64, theta: f64) -> f64 {
        debug_assert!(k > 0.0 && theta > 0.0);
        if k < 1.0 {
            // Gamma(k) = Gamma(k+1) * U^{1/k}
            let u = self.f64().max(1e-300);
            return self.gamma(k + 1.0, theta) * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v * theta;
            }
        }
    }

    /// Log-normal with underlying normal(mu, sigma). Used for token-length
    /// distributions (heavy right tail, as in production LLM traces).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Sample an index from a discrete weight vector (weights need not sum
    /// to 1). Panics on empty/non-positive-total weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted(): non-positive total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg64::new(9);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg64::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gamma_mean_and_var() {
        let mut r = Pcg64::new(13);
        let (k, theta) = (0.5, 2.0); // mean 1, var 2 (CV^2 = 2 -> bursty)
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gamma(k, theta)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.05, "mean={mean}");
        assert!((var - 2.0).abs() < 0.3, "var={var}");
    }

    #[test]
    fn weighted_prefers_heavy_bucket() {
        let mut r = Pcg64::new(17);
        let w = [1.0, 8.0, 1.0];
        let mut counts = [0u32; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert!(counts[1] > counts[0] * 4 && counts[1] > counts[2] * 4);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(19);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn state_parts_round_trip_resumes_the_stream() {
        let mut a = Pcg64::new(77);
        for _ in 0..13 {
            a.next_u64();
        }
        let (s, i) = a.state_parts();
        let mut b = Pcg64::from_state_parts(s, i);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut a = Pcg64::new(23);
        let mut c1 = a.fork();
        let mut c2 = a.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }
}
