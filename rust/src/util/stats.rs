//! Small statistics toolkit: summaries, percentiles, correlation, EWMA,
//! and running-window averages used by the burst analytics and metrics.

use crate::util::json::Json;

/// Mean of a slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0.0 for fewer than 2 samples.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Coefficient of variation (stddev / mean); 0.0 when the mean is ~0.
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m.abs() < 1e-12 {
        return 0.0;
    }
    stddev(xs) / m
}

/// Linear-interpolated percentile, `q` in [0, 100]. Sorts a copy.
/// Returns 0.0 for empty input.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    percentile_sorted(&v, q)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 100.0);
    let pos = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Pearson correlation coefficient between two equal-length series.
/// Returns 0.0 if either series is constant or lengths mismatch/empty.
/// Used for the paper's Fig. 11 provisioned-vs-required analysis.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    if xs.len() != ys.len() || xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        let dx = x - mx;
        let dy = y - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx <= 1e-12 || syy <= 1e-12 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Exponentially-weighted moving average with configurable smoothing.
/// Drives the online velocity estimates and the burst detector baseline.
#[derive(Clone, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha), "alpha must be in [0,1]");
        Ewma { alpha, value: None }
    }

    /// EWMA whose weight corresponds to a given half-life in samples.
    pub fn with_half_life(samples: f64) -> Self {
        let alpha = 1.0 - 0.5f64.powf(1.0 / samples.max(1e-9));
        Ewma::new(alpha)
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn get_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }

    pub fn reset(&mut self) {
        self.value = None;
    }

    /// Bit-exact serialization for checkpoint/restore (sim::snapshot).
    pub fn to_snapshot(&self) -> Json {
        Json::obj()
            .set("alpha", Json::f64_bits(self.alpha))
            .set(
                "value",
                match self.value {
                    None => Json::Null,
                    Some(v) => Json::f64_bits(v),
                },
            )
    }

    /// Rebuild from [`Ewma::to_snapshot`] output.
    pub fn from_snapshot(j: &Json) -> anyhow::Result<Ewma> {
        let alpha = j
            .get("alpha")
            .and_then(Json::as_f64_bits)
            .ok_or_else(|| anyhow::anyhow!("ewma snapshot: missing `alpha`"))?;
        let value = match j.get("value") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_f64_bits()
                    .ok_or_else(|| anyhow::anyhow!("ewma snapshot: bad `value`"))?,
            ),
        };
        Ok(Ewma { alpha, value })
    }
}

/// Fixed-duration sliding-window sum/rate over timestamped samples.
/// Matches the paper's "1-minute sliding window" running-average analysis
/// and the short windows the autoscalers act on.
#[derive(Clone, Debug)]
pub struct SlidingWindow {
    window: f64,
    samples: std::collections::VecDeque<(f64, f64)>, // (time, value)
    sum: f64,
}

impl SlidingWindow {
    pub fn new(window_secs: f64) -> Self {
        assert!(window_secs > 0.0);
        SlidingWindow {
            window: window_secs,
            samples: std::collections::VecDeque::new(),
            sum: 0.0,
        }
    }

    /// Record `value` at time `now` (seconds); evicts expired samples.
    pub fn push(&mut self, now: f64, value: f64) {
        self.samples.push_back((now, value));
        self.sum += value;
        self.evict(now);
    }

    /// Drop samples older than `now - window`.
    pub fn evict(&mut self, now: f64) {
        while let Some(&(t, v)) = self.samples.front() {
            if t < now - self.window {
                self.samples.pop_front();
                self.sum -= v;
            } else {
                break;
            }
        }
    }

    /// Sum of values currently inside the window.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Sum divided by window length: a per-second rate.
    pub fn rate(&self) -> f64 {
        self.sum / self.window
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn window_secs(&self) -> f64 {
        self.window
    }

    /// Bit-exact serialization for checkpoint/restore (sim::snapshot).
    /// The running `sum` is stored verbatim: it accumulates additions and
    /// subtractions in a specific order, so recomputing it from the
    /// samples would not reproduce the same bits.
    pub fn to_snapshot(&self) -> Json {
        Json::obj()
            .set("window", Json::f64_bits(self.window))
            .set("sum", Json::f64_bits(self.sum))
            .set(
                "samples",
                Json::Arr(
                    self.samples
                        .iter()
                        .map(|(t, v)| Json::Arr(vec![Json::f64_bits(*t), Json::f64_bits(*v)]))
                        .collect(),
                ),
            )
    }

    /// Rebuild from [`SlidingWindow::to_snapshot`] output.
    pub fn from_snapshot(j: &Json) -> anyhow::Result<SlidingWindow> {
        let bits = |key: &str| -> anyhow::Result<f64> {
            j.get(key)
                .and_then(Json::as_f64_bits)
                .ok_or_else(|| anyhow::anyhow!("sliding-window snapshot: missing `{key}`"))
        };
        let window = bits("window")?;
        anyhow::ensure!(window > 0.0, "sliding-window snapshot: non-positive window");
        let sum = bits("sum")?;
        let mut samples = std::collections::VecDeque::new();
        for (i, s) in j
            .get("samples")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("sliding-window snapshot: missing `samples`"))?
            .iter()
            .enumerate()
        {
            let pair = s.as_arr().filter(|p| p.len() == 2).ok_or_else(|| {
                anyhow::anyhow!("sliding-window snapshot: sample {i} is not a pair")
            })?;
            let t = pair[0]
                .as_f64_bits()
                .ok_or_else(|| anyhow::anyhow!("sliding-window snapshot: bad sample time"))?;
            let v = pair[1]
                .as_f64_bits()
                .ok_or_else(|| anyhow::anyhow!("sliding-window snapshot: bad sample value"))?;
            samples.push_back((t, v));
        }
        Ok(SlidingWindow { window, samples, sum })
    }
}

/// Summary of a latency distribution used throughout the reports.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        Summary {
            count: v.len(),
            mean: mean(&v),
            p50: percentile_sorted(&v, 50.0),
            p90: percentile_sorted(&v, 90.0),
            p99: percentile_sorted(&v, 99.0),
            max: *v.last().unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_empty_and_single() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn pearson_perfect_and_inverse() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let z = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_zero() {
        let x = [1.0, 1.0, 1.0];
        let y = [1.0, 2.0, 3.0];
        assert_eq!(pearson(&x, &y), 0.0);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        for _ in 0..60 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn ewma_half_life() {
        let mut e = Ewma::with_half_life(10.0);
        e.update(0.0);
        for _ in 0..10 {
            e.update(1.0);
        }
        // After one half-life of 1.0-valued updates from 0, ~half way.
        let v = e.get().unwrap();
        assert!((v - 0.5).abs() < 0.05, "v={v}");
    }

    #[test]
    fn sliding_window_evicts() {
        let mut w = SlidingWindow::new(1.0);
        w.push(0.0, 5.0);
        w.push(0.5, 5.0);
        assert_eq!(w.sum(), 10.0);
        w.push(1.6, 1.0); // evicts both earlier samples (t < 0.6)
        assert_eq!(w.sum(), 1.0);
        w.evict(3.0);
        assert_eq!(w.sum(), 0.0);
        assert!(w.is_empty());
    }

    #[test]
    fn window_and_ewma_snapshots_round_trip() {
        let mut w = SlidingWindow::new(2.5);
        w.push(0.1, 3.0);
        w.push(0.7, 1.5);
        w.push(1.9, 0.25);
        w.evict(2.0);
        let back = SlidingWindow::from_snapshot(&w.to_snapshot()).unwrap();
        assert_eq!(back.window_secs(), w.window_secs());
        assert_eq!(back.sum().to_bits(), w.sum().to_bits());
        assert_eq!(back.len(), w.len());

        let mut e = Ewma::with_half_life(7.0);
        e.update(2.0);
        e.update(5.5);
        let eb = Ewma::from_snapshot(&e.to_snapshot()).unwrap();
        assert_eq!(eb.get().unwrap().to_bits(), e.get().unwrap().to_bits());
        let empty = Ewma::from_snapshot(&Ewma::new(0.3).to_snapshot()).unwrap();
        assert_eq!(empty.get(), None);
    }

    #[test]
    fn summary_of_known() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.count, 5);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.max, 100.0);
        assert!(s.p99 > 4.0);
    }
}
