//! ASCII table + CSV rendering for bench/report output.
//!
//! Every bench target prints the paper-shaped rows with these helpers and
//! mirrors them to `results/*.csv` for plotting.

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-aligned ASCII table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Table {
        Table {
            title: title.to_string(),
            ..Default::default()
        }
    }

    pub fn header(mut self, cols: &[&str]) -> Table {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cols: Vec<String>) -> &mut Self {
        assert_eq!(
            cols.len(),
            self.header.len(),
            "row arity mismatch in table `{}`",
            self.title
        );
        self.rows.push(cols);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let rule: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<width$} ", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header));
        let _ = writeln!(out, "{rule}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row));
        }
        out
    }

    /// Render as CSV (header + rows).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write CSV under `results/<name>.csv`, creating the directory.
    pub fn save_csv(&self, name: &str) -> anyhow::Result<std::path::PathBuf> {
        let dir = Path::new("results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Format a float with `digits` decimal places, trimming to a compact form.
pub fn fnum(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

/// Format a fraction as a percentage string, e.g. 0.934 -> "93.4%".
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long-name"));
        // all data lines have equal width
        let lines: Vec<&str> = s.lines().skip(1).collect();
        assert_eq!(lines[1].len(), lines[2].len().max(lines[1].len()));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x").header(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x").header(&["a", "b"]);
        t.row(vec!["has,comma".into(), "has\"quote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.934), "93.4%");
        assert_eq!(fnum(1.23456, 2), "1.23");
    }
}
