//! Minimal TOML reader for scenario/suite files.
//!
//! The offline crate set has no `toml` (or serde), so this hand-rolled
//! parser covers the subset the scenario library uses and lowers it into
//! the [`Json`] value model — scenario deserialization is then
//! format-agnostic (`report::scenario` consumes `Json` whether the file
//! was TOML or JSON).
//!
//! Supported: `key = value` pairs, `[table.path]` headers, `[[array]]`
//! array-of-tables headers (dotted paths traverse the *last* element of
//! intermediate arrays, per TOML semantics), basic `"…"` and literal
//! `'…'` strings, numbers, booleans, inline arrays (multi-line allowed)
//! and inline tables, and `#` comments. Not supported (not needed by
//! scenario files): dates, multi-line strings, dotted keys.

use super::json::Json;
use std::collections::BTreeMap;

/// Parse TOML text into a [`Json`] object.
pub fn parse(text: &str) -> anyhow::Result<Json> {
    let mut root = Json::obj();
    // Path of the table currently being filled by `key = value` lines.
    let mut current: Vec<String> = Vec::new();
    let lines: Vec<&str> = text.lines().collect();
    let mut i = 0usize;
    while i < lines.len() {
        let lineno = i + 1;
        let line = strip_comment(lines[i]);
        i += 1;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            let path = parse_path(inner, lineno)?;
            let (last, parents) = path.split_last().expect("parse_path is non-empty");
            let table = descend(&mut root, parents, lineno)?;
            let map = as_obj(table, last, lineno)?;
            let entry = map
                .entry(last.clone())
                .or_insert_with(|| Json::Arr(Vec::new()));
            match entry {
                Json::Arr(items) => items.push(Json::obj()),
                _ => anyhow::bail!("line {lineno}: `{last}` is not an array of tables"),
            }
            current = path;
        } else if let Some(inner) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            let path = parse_path(inner, lineno)?;
            descend(&mut root, &path, lineno)?;
            current = path;
        } else if let Some(eq) = find_unquoted(line, '=') {
            let key = line[..eq].trim();
            anyhow::ensure!(
                !key.is_empty() && key.chars().all(is_bare_key_char),
                "line {lineno}: bad key `{key}`"
            );
            // Collect the value, joining following lines while brackets
            // are unbalanced (multi-line arrays / inline tables).
            let mut value_text = line[eq + 1..].trim().to_string();
            while bracket_depth(&value_text) > 0 && i < lines.len() {
                value_text.push(' ');
                value_text.push_str(strip_comment(lines[i]).trim());
                i += 1;
            }
            let value = parse_value(value_text.trim(), lineno)?;
            let table = descend(&mut root, &current, lineno)?;
            let map = as_obj(table, key, lineno)?;
            anyhow::ensure!(
                !map.contains_key(key),
                "line {lineno}: duplicate key `{key}`"
            );
            map.insert(key.to_string(), value);
        } else {
            anyhow::bail!("line {lineno}: expected `key = value` or a [table] header");
        }
    }
    Ok(root)
}

fn is_bare_key_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_' || c == '-'
}

fn parse_path(inner: &str, lineno: usize) -> anyhow::Result<Vec<String>> {
    let segs: Vec<String> = inner.split('.').map(|s| s.trim().to_string()).collect();
    anyhow::ensure!(
        !segs.is_empty() && segs.iter().all(|s| !s.is_empty() && s.chars().all(is_bare_key_char)),
        "line {lineno}: bad table path `{inner}`"
    );
    Ok(segs)
}

/// Walk `path` from `root`, creating empty tables for missing segments and
/// resolving arrays-of-tables to their last element (TOML: a `[a.b]`
/// header after `[[a]]` opens a table inside the most recent `a` entry).
fn descend<'a>(root: &'a mut Json, path: &[String], lineno: usize) -> anyhow::Result<&'a mut Json> {
    let mut cur = root;
    for seg in path {
        let map = match cur {
            Json::Obj(m) => m,
            _ => anyhow::bail!("line {lineno}: `{seg}` traverses a non-table value"),
        };
        let next = map.entry(seg.clone()).or_insert_with(Json::obj);
        cur = match next {
            Json::Arr(items) => items
                .last_mut()
                .ok_or_else(|| anyhow::anyhow!("line {lineno}: array of tables `{seg}` is empty"))?,
            other => other,
        };
    }
    Ok(cur)
}

fn as_obj<'a>(
    v: &'a mut Json,
    key: &str,
    lineno: usize,
) -> anyhow::Result<&'a mut BTreeMap<String, Json>> {
    match v {
        Json::Obj(m) => Ok(m),
        _ => anyhow::bail!("line {lineno}: cannot insert `{key}` into a non-table value"),
    }
}

/// Drive `f(index, byte)` for every byte of `line` that sits outside
/// string literals; `f` returning `true` stops the scan. Escapes inside
/// basic strings are tracked as a state machine (not a look-behind), so a
/// string ending in an escaped backslash (`"dir\\"`) closes correctly.
fn scan_outside_strings(line: &str, mut f: impl FnMut(usize, u8) -> bool) {
    let mut in_basic = false;
    let mut in_literal = false;
    let mut escaped = false;
    for (i, &b) in line.as_bytes().iter().enumerate() {
        if in_basic {
            if escaped {
                escaped = false;
            } else if b == b'\\' {
                escaped = true;
            } else if b == b'"' {
                in_basic = false;
            }
        } else if in_literal {
            if b == b'\'' {
                in_literal = false;
            }
        } else {
            match b {
                b'"' => in_basic = true,
                b'\'' => in_literal = true,
                _ => {
                    if f(i, b) {
                        return;
                    }
                }
            }
        }
    }
}

/// Strip a `#` comment, ignoring `#` inside quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut cut = None;
    scan_outside_strings(line, |i, b| {
        if b == b'#' {
            cut = Some(i);
            true
        } else {
            false
        }
    });
    match cut {
        Some(i) => &line[..i],
        None => line,
    }
}

/// Index of the first unquoted occurrence of `needle`.
fn find_unquoted(line: &str, needle: char) -> Option<usize> {
    let mut found = None;
    scan_outside_strings(line, |i, b| {
        if b == needle as u8 {
            found = Some(i);
            true
        } else {
            false
        }
    });
    found
}

/// Net `[`/`{` depth of `text`, ignoring brackets inside strings.
fn bracket_depth(text: &str) -> i32 {
    let mut depth = 0i32;
    scan_outside_strings(text, |_, b| {
        match b {
            b'[' | b'{' => depth += 1,
            b']' | b'}' => depth -= 1,
            _ => {}
        }
        false
    });
    depth
}

/// Recursive-descent value parser over one (joined) value string.
struct VParser<'a> {
    bytes: &'a [u8],
    pos: usize,
    lineno: usize,
}

fn parse_value(text: &str, lineno: usize) -> anyhow::Result<Json> {
    let mut p = VParser {
        bytes: text.as_bytes(),
        pos: 0,
        lineno,
    };
    let v = p.value()?;
    p.skip_ws();
    anyhow::ensure!(
        p.pos == p.bytes.len(),
        "line {lineno}: trailing characters after value in `{text}`"
    );
    Ok(v)
}

impl<'a> VParser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'"') => self.basic_string(),
            Some(b'\'') => self.literal_string(),
            Some(b'[') => self.array(),
            Some(b'{') => self.inline_table(),
            Some(_) => self.scalar(),
            None => anyhow::bail!("line {}: missing value", self.lineno),
        }
    }

    fn basic_string(&mut self) -> anyhow::Result<Json> {
        self.pos += 1; // opening quote
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("line {}: unterminated string", self.lineno),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(Json::Str(out));
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        other => anyhow::bail!(
                            "line {}: bad escape {:?}",
                            self.lineno,
                            other.map(|c| c as char)
                        ),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn literal_string(&mut self) -> anyhow::Result<Json> {
        self.pos += 1;
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b == b'\'' {
                let s = std::str::from_utf8(&self.bytes[start..self.pos])?.to_string();
                self.pos += 1;
                return Ok(Json::Str(s));
            }
            self.pos += 1;
        }
        anyhow::bail!("line {}: unterminated literal string", self.lineno)
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.pos += 1; // '['
        let mut items = Vec::new();
        loop {
            self.skip_ws();
            match self.peek() {
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                None => anyhow::bail!("line {}: unterminated array", self.lineno),
                _ => {}
            }
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1; // trailing comma before ']' is fine
                }
                Some(b']') => {}
                other => anyhow::bail!(
                    "line {}: expected `,` or `]` in array, found {:?}",
                    self.lineno,
                    other.map(|c| c as char)
                ),
            }
        }
    }

    fn inline_table(&mut self) -> anyhow::Result<Json> {
        self.pos += 1; // '{'
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let start = self.pos;
            while self
                .peek()
                .map(|b| is_bare_key_char(b as char))
                .unwrap_or(false)
            {
                self.pos += 1;
            }
            let key = std::str::from_utf8(&self.bytes[start..self.pos])?.to_string();
            anyhow::ensure!(!key.is_empty(), "line {}: bad inline-table key", self.lineno);
            self.skip_ws();
            anyhow::ensure!(
                self.peek() == Some(b'='),
                "line {}: expected `=` after inline-table key `{key}`",
                self.lineno
            );
            self.pos += 1;
            let v = self.value()?;
            anyhow::ensure!(
                !map.contains_key(&key),
                "line {}: duplicate inline-table key `{key}`",
                self.lineno
            );
            map.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => anyhow::bail!(
                    "line {}: expected `,` or `}}` in inline table, found {:?}",
                    self.lineno,
                    other.map(|c| c as char)
                ),
            }
        }
    }

    fn scalar(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b == b',' || b == b']' || b == b'}' || b.is_ascii_whitespace() {
                break;
            }
            self.pos += 1;
        }
        let token = std::str::from_utf8(&self.bytes[start..self.pos])?;
        match token {
            "true" => Ok(Json::Bool(true)),
            "false" => Ok(Json::Bool(false)),
            _ => {
                let cleaned = token.replace('_', "");
                cleaned
                    .parse::<f64>()
                    .map(Json::Num)
                    .map_err(|_| anyhow::anyhow!("line {}: bad value `{token}`", self.lineno))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_keys_and_types() {
        let j = parse(
            r#"
# a comment
name = "smoke"   # trailing comment
rps = 22.5
seed = 42
deep = true
tag = 'lit # not a comment'
"#,
        )
        .unwrap();
        assert_eq!(j.get("name").unwrap().as_str(), Some("smoke"));
        assert_eq!(j.get("rps").unwrap().as_f64(), Some(22.5));
        assert_eq!(j.get("seed").unwrap().as_f64(), Some(42.0));
        assert_eq!(j.get("deep").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("tag").unwrap().as_str(), Some("lit # not a comment"));
    }

    #[test]
    fn nested_tables_and_arrays_of_tables() {
        let j = parse(
            r#"
name = "suite"

[[scenarios]]
name = "a"

[scenarios.workload]
kind = "synthetic"
rps = 5.0

[[scenarios.transforms]]
op = "window"
t0 = 0.0
t1 = 60.0

[[scenarios]]
name = "b"

[scenarios.workload]
kind = "replay"
path = "examples/traces/azure_conv_sample.csv"
"#,
        )
        .unwrap();
        let scenarios = j.get("scenarios").unwrap().as_arr().unwrap();
        assert_eq!(scenarios.len(), 2);
        assert_eq!(scenarios[0].get("name").unwrap().as_str(), Some("a"));
        assert_eq!(
            scenarios[0].get_path(&["workload", "kind"]).unwrap().as_str(),
            Some("synthetic")
        );
        let tr = scenarios[0].get("transforms").unwrap().as_arr().unwrap();
        assert_eq!(tr[0].get("op").unwrap().as_str(), Some("window"));
        assert_eq!(tr[0].get("t1").unwrap().as_f64(), Some(60.0));
        assert_eq!(
            scenarios[1].get_path(&["workload", "path"]).unwrap().as_str(),
            Some("examples/traces/azure_conv_sample.csv")
        );
    }

    #[test]
    fn inline_arrays_and_tables_multiline() {
        let j = parse(
            r#"
policies = ["tokenscale", "distserve"]
windows = [
    { start_s = 10.0, len_s = 5.0, rate_factor = 3.0 },
    { start_s = 40.0, len_s = 5.0, rate_factor = 2.0 },
]
"#,
        )
        .unwrap();
        let pols = j.get("policies").unwrap().as_arr().unwrap();
        assert_eq!(pols[1].as_str(), Some("distserve"));
        let wins = j.get("windows").unwrap().as_arr().unwrap();
        assert_eq!(wins.len(), 2);
        assert_eq!(wins[0].get("rate_factor").unwrap().as_f64(), Some(3.0));
        assert_eq!(wins[1].get("start_s").unwrap().as_f64(), Some(40.0));
    }

    #[test]
    fn escaped_backslash_closes_string_before_comment() {
        // The closing quote after an escaped backslash really closes the
        // string, so the trailing comment is stripped.
        let j = parse(r#"path = "dir\\" # trailing comment"#).unwrap();
        assert_eq!(j.get("path").unwrap().as_str(), Some("dir\\"));
    }

    #[test]
    fn duplicate_inline_table_key_rejected() {
        let e = parse("w = { a = 1.0, a = 2.0 }").unwrap_err().to_string();
        assert!(e.contains("duplicate inline-table key"), "{e}");
    }

    #[test]
    fn errors_carry_line_numbers() {
        for (text, frag) in [
            ("= 3", "key"),
            ("x = ", "missing value"),
            ("x = nope", "bad value"),
            ("x = 1\nx = 2", "duplicate"),
            ("just a line", "expected"),
            ("[a]\nx = 1\n[a.x]\ny = 2", "non-table"),
        ] {
            let e = parse(text).unwrap_err().to_string();
            assert!(e.contains(frag), "`{text}` -> `{e}`");
        }
    }

    #[test]
    fn matches_json_model() {
        let toml = parse(
            r#"
name = "x"
[nested]
a = 1.0
b = ["y", 2.0]
"#,
        )
        .unwrap();
        let json = Json::parse(r#"{"name":"x","nested":{"a":1,"b":["y",2]}}"#).unwrap();
        assert_eq!(toml, json);
    }
}
