//! Closed-form Token Velocity estimates from the engine performance model.
//!
//! These mirror what the paper's Offline Profiler measures on hardware
//! (§IV-B): the profiler module additionally derives the same quantities
//! by saturation sweeps on the simulator, and the Table II bench compares
//! both against the published values.

use crate::perfmodel::{EngineModel, LinkSpec};
use crate::workload::{all_buckets, Bucket, BucketScheme};

/// Maximum sustained prefill rate (input tokens/s) for an engine, at the
/// given characteristic prompt length. Prefill is compute-bound, so this is
/// the prompt length divided by its batched processing time; longer prompts
/// amortize the per-iteration overhead better.
pub fn prefill_velocity(engine: &EngineModel, avg_prompt_tokens: usize) -> f64 {
    let n = avg_prompt_tokens.max(1);
    n as f64 / engine.prefill_time(n)
}

/// Maximum KVC transfer rate expressed in tokens/s over the inter-node
/// fabric.
pub fn network_velocity(engine: &EngineModel, link: &LinkSpec) -> f64 {
    link.eff_rdma_bytes() / engine.model.kv_bytes_per_token()
}

/// Decode velocity for a request-type bucket (Eq. 1): the rate at which a
/// decoder *releases* KV tokens via completions.
///
/// With continuous batching at steady state on bucket (L_in, L_out):
/// batch size `B` is memory-capacity-bound (capped by the engine's max
/// batch), a request completes every `L_out` iterations, and each
/// completion releases `L_in + L_out` tokens:
/// `V_D = B · (L_in + L_out) / (L_out · t_iter)`.
pub fn decode_velocity(engine: &EngineModel, input_tokens: usize, output_tokens: usize) -> f64 {
    let max_batch = 256usize;
    let total = (input_tokens + output_tokens) as f64;
    let cap = engine.kv_capacity_tokens();
    let b = ((cap / total).floor() as usize).clamp(1, max_batch);
    // Mean context over a request's residency: input + half the output.
    let avg_ctx = input_tokens as f64 + output_tokens as f64 / 2.0;
    let t_iter = engine.decode_iter_time(b, avg_ctx);
    b as f64 * total / (output_tokens.max(1) as f64 * t_iter)
}

/// A complete offline velocity profile for one deployment: what the
/// paper's Offline Profiler hands the Scaler.
#[derive(Clone, Debug)]
pub struct VelocityProfile {
    /// Prefill velocity `V_P` (input tokens/s per prefiller).
    pub prefill: f64,
    /// Network velocity `V_N` (tokens/s per transfer path).
    pub network: f64,
    /// Per-bucket decode velocities `V_D^(b)`, indexed by `Bucket::index()`.
    pub decode: [f64; 9],
}

impl VelocityProfile {
    /// Build the profile analytically for an engine + link, using the
    /// Table II bucket representatives and a characteristic prompt length.
    pub fn analytic(engine: &EngineModel, link: &LinkSpec, avg_prompt_tokens: usize) -> Self {
        let scheme = BucketScheme::default();
        let mut decode = [0.0; 9];
        for b in all_buckets() {
            let (i, o) = scheme.representative(b);
            decode[b.index()] = decode_velocity(engine, i, o);
        }
        VelocityProfile {
            prefill: prefill_velocity(engine, avg_prompt_tokens),
            network: network_velocity(engine, link),
            decode,
        }
    }

    pub fn decode_of(&self, b: Bucket) -> f64 {
        self.decode[b.index()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::catalog;

    fn llama_a100() -> EngineModel {
        EngineModel::new(
            catalog::model("llama-3.1-8b").unwrap(),
            catalog::gpu("a100-40g").unwrap(),
            1,
        )
    }

    fn qwen_a100_tp4() -> EngineModel {
        EngineModel::new(
            catalog::model("qwen-2.5-32b").unwrap(),
            catalog::gpu("a100-40g").unwrap(),
            4,
        )
    }

    #[test]
    fn prefill_velocity_in_table1_ballpark() {
        // The paper's Table I sets TokenScale's prefiller threshold at
        // 14 K tok/s for Llama-8B-class prefill on A100.
        let v = prefill_velocity(&llama_a100(), 2048);
        assert!((4_000.0..30_000.0).contains(&v), "V_P={v}");
    }

    #[test]
    fn decode_velocity_matches_table2_shape() {
        // Table II (Llama-3.1-8B TP=1, A100): S-S 23535, S-L 5138,
        // L-S 39551, L-L 6495 tok/s. Check ordering + rough magnitude.
        let e = llama_a100();
        let ss = decode_velocity(&e, 256, 100);
        let sl = decode_velocity(&e, 256, 610);
        let ls = decode_velocity(&e, 8192, 100);
        let ll = decode_velocity(&e, 8192, 610);
        assert!(ls > ss, "L-S {ls} should beat S-S {ss}");
        assert!(ss > sl, "S-S {ss} should beat S-L {sl}");
        assert!(ls > ll, "L-S {ls} should beat L-L {ll}");
        // within 2x of the published values
        for (ours, paper) in [(ss, 23535.0), (sl, 5138.0), (ls, 39551.0), (ll, 6495.0)] {
            let ratio = ours / paper;
            assert!(
                (0.5..2.0).contains(&ratio),
                "velocity {ours:.0} vs paper {paper:.0} (ratio {ratio:.2})"
            );
        }
    }

    #[test]
    fn network_velocity_dominates() {
        // Fig. 7 conclusion: network velocity far exceeds prefill/decode.
        let e = llama_a100();
        let link = catalog::link("a100-cluster").unwrap();
        let vn = network_velocity(&e, &link);
        let vp = prefill_velocity(&e, 2048);
        assert!(vn > 2.0 * vp, "V_N {vn} should dominate V_P {vp}");
    }

    #[test]
    fn profile_has_all_buckets() {
        let e = qwen_a100_tp4();
        let link = catalog::link("a100-cluster").unwrap();
        let p = VelocityProfile::analytic(&e, &link, 1024);
        assert!(p.decode.iter().all(|v| *v > 0.0));
        assert!(p.prefill > 0.0 && p.network > 0.0);
    }

    #[test]
    fn bigger_model_lower_prefill_velocity_at_equal_tp() {
        let small = prefill_velocity(&llama_a100(), 2048);
        let big_tp1 = prefill_velocity(
            &EngineModel::new(
                catalog::model("qwen-2.5-32b").unwrap(),
                catalog::gpu("a100-40g").unwrap(),
                1,
            ),
            2048,
        );
        // 4x the parameters on the same GPU -> ~4x slower prefill.
        assert!(
            big_tp1 < small / 2.0,
            "qwen32 tp1 {big_tp1} vs llama8 {small}"
        );
        // At TP=4 the 32B model roughly recovers the 8B's per-instance
        // velocity (4x flops vs 4x params) — the paper's Fig. 7 shows the
        // same near-flat scaling across Qwen sizes at fixed cluster share.
        let big_tp4 = prefill_velocity(&qwen_a100_tp4(), 2048);
        let ratio = big_tp4 / small;
        assert!((0.5..2.0).contains(&ratio), "ratio={ratio}");
    }
}
