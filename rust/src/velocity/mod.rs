//! Token Velocity (§III-B): the paper's leading capacity metric.
//!
//! *"The maximum number of tokens that the instance can release in a
//! second with the current allocated resource."* Three stage velocities
//! unify the PD pipeline:
//!
//! - **Prefill velocity** `V_P` — input tokens/s a prefiller sustains
//!   (compute-bound, constant per model×GPU×TP).
//! - **Network velocity** `V_N` — KVC tokens/s the interconnect moves.
//! - **Decode velocity** `V_D` — tokens/s a decoder *releases* (memory
//!   freed by completing requests), per request-type bucket (Eq. 1).

pub mod analytic;
pub mod online;

pub use analytic::{decode_velocity, network_velocity, prefill_velocity, VelocityProfile};
pub use online::OnlineVelocity;
