//! Online decode-velocity measurement (Eq. 1): `V_D = Σ L_r / TPOT` over
//! recently completed requests — the runtime-status signal the Scaler
//! cross-checks against the offline profile.

use crate::util::json::Json;
use crate::util::stats::{Ewma, SlidingWindow};

/// Measures realized decode velocity from the completion stream.
#[derive(Clone, Debug)]
pub struct OnlineVelocity {
    /// Released tokens (L_r = input + output) over a sliding window.
    released: SlidingWindow,
    /// Smoothed TPOT of completions.
    tpot: Ewma,
}

impl OnlineVelocity {
    pub fn new(window_s: f64) -> Self {
        OnlineVelocity {
            released: SlidingWindow::new(window_s),
            tpot: Ewma::with_half_life(32.0),
        }
    }

    /// Record a completion releasing `tokens` KV tokens with measured
    /// per-token latency `tpot_s`.
    pub fn on_completion(&mut self, now: f64, tokens: usize, tpot_s: f64) {
        self.released.push(now, tokens as f64);
        if tpot_s > 0.0 {
            self.tpot.update(tpot_s);
        }
    }

    /// Realized release rate (tokens/s) over the window.
    pub fn release_rate(&mut self, now: f64) -> f64 {
        self.released.evict(now);
        self.released.rate()
    }

    /// Smoothed observed TPOT, if any completions were seen.
    pub fn observed_tpot(&self) -> Option<f64> {
        self.tpot.get()
    }

    /// Bit-exact serialization for checkpoint/restore (sim::snapshot).
    pub fn to_snapshot(&self) -> Json {
        Json::obj()
            .set("released", self.released.to_snapshot())
            .set("tpot", self.tpot.to_snapshot())
    }

    /// Rebuild from [`OnlineVelocity::to_snapshot`] output.
    pub fn from_snapshot(j: &Json) -> anyhow::Result<OnlineVelocity> {
        let get = |key: &str| -> anyhow::Result<&Json> {
            j.get(key)
                .ok_or_else(|| anyhow::anyhow!("online-velocity snapshot: missing `{key}`"))
        };
        Ok(OnlineVelocity {
            released: SlidingWindow::from_snapshot(get("released")?)?,
            tpot: Ewma::from_snapshot(get("tpot")?)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn release_rate_tracks_completions() {
        let mut v = OnlineVelocity::new(10.0);
        for i in 0..10 {
            v.on_completion(i as f64, 500, 0.05);
        }
        // 5000 tokens over a 10 s window.
        let r = v.release_rate(9.9);
        assert!((r - 500.0).abs() < 60.0, "rate={r}");
    }

    #[test]
    fn old_completions_expire() {
        let mut v = OnlineVelocity::new(5.0);
        v.on_completion(0.0, 1000, 0.05);
        assert!(v.release_rate(1.0) > 0.0);
        assert_eq!(v.release_rate(100.0), 0.0);
    }

    #[test]
    fn snapshot_round_trips_measurement_state() {
        let mut v = OnlineVelocity::new(10.0);
        for i in 0..8 {
            v.on_completion(i as f64 * 0.5, 300 + i, 0.04 + 0.001 * i as f64);
        }
        let back = OnlineVelocity::from_snapshot(&v.to_snapshot()).unwrap();
        assert_eq!(
            back.observed_tpot().unwrap().to_bits(),
            v.observed_tpot().unwrap().to_bits()
        );
        let mut a = v;
        let mut b = back;
        assert_eq!(a.release_rate(5.0).to_bits(), b.release_rate(5.0).to_bits());
    }

    #[test]
    fn tpot_smooths() {
        let mut v = OnlineVelocity::new(5.0);
        for _ in 0..50 {
            v.on_completion(0.0, 10, 0.08);
        }
        let t = v.observed_tpot().unwrap();
        assert!((t - 0.08).abs() < 1e-6);
    }
}
