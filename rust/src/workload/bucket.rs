//! Request-type buckets (Table II): the 3×3 grid of short/medium/long
//! inputs × short/medium/long outputs the decoder autoscaler sums over.

/// Length class for either input or output.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LenClass {
    Short,
    Medium,
    Long,
}

impl LenClass {
    pub fn label(self) -> &'static str {
        match self {
            LenClass::Short => "S",
            LenClass::Medium => "M",
            LenClass::Long => "L",
        }
    }
}

/// A (input-class, output-class) bucket.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Bucket {
    pub input: LenClass,
    pub output: LenClass,
}

impl Bucket {
    pub fn new(input: LenClass, output: LenClass) -> Bucket {
        Bucket { input, output }
    }

    /// "S-M"-style label matching Table II's header row.
    pub fn label(&self) -> String {
        format!("{}-{}", self.input.label(), self.output.label())
    }

    /// Index 0..9 in row-major (input, output) order.
    pub fn index(&self) -> usize {
        let i = match self.input {
            LenClass::Short => 0,
            LenClass::Medium => 1,
            LenClass::Long => 2,
        };
        let o = match self.output {
            LenClass::Short => 0,
            LenClass::Medium => 1,
            LenClass::Long => 2,
        };
        i * 3 + o
    }

    pub fn from_index(idx: usize) -> Bucket {
        let classes = [LenClass::Short, LenClass::Medium, LenClass::Long];
        Bucket::new(classes[idx / 3], classes[idx % 3])
    }
}

/// Classification thresholds; boundaries follow the paper's bucket
/// representatives (256 / 1024 / 8192 input, 100 / 350 / 610 output).
#[derive(Clone, Copy, Debug)]
pub struct BucketScheme {
    pub input_short_max: usize,
    pub input_medium_max: usize,
    pub output_short_max: usize,
    pub output_medium_max: usize,
}

impl Default for BucketScheme {
    fn default() -> Self {
        BucketScheme {
            input_short_max: 512,   // S-rep 256
            input_medium_max: 3072, // M-rep 1024, L-rep 8192
            output_short_max: 200,  // S-rep 100
            output_medium_max: 480, // M-rep 350, L-rep 610
        }
    }
}

impl BucketScheme {
    pub fn classify_input(&self, tokens: usize) -> LenClass {
        if tokens <= self.input_short_max {
            LenClass::Short
        } else if tokens <= self.input_medium_max {
            LenClass::Medium
        } else {
            LenClass::Long
        }
    }

    pub fn classify_output(&self, tokens: usize) -> LenClass {
        if tokens <= self.output_short_max {
            LenClass::Short
        } else if tokens <= self.output_medium_max {
            LenClass::Medium
        } else {
            LenClass::Long
        }
    }

    pub fn classify(&self, input_tokens: usize, output_tokens: usize) -> Bucket {
        Bucket::new(
            self.classify_input(input_tokens),
            self.classify_output(output_tokens),
        )
    }

    /// Representative (input, output) lengths for each bucket — the exact
    /// values Table II profiles with.
    pub fn representative(&self, b: Bucket) -> (usize, usize) {
        let input = match b.input {
            LenClass::Short => 256,
            LenClass::Medium => 1024,
            LenClass::Long => 8192,
        };
        let output = match b.output {
            LenClass::Short => 100,
            LenClass::Medium => 350,
            LenClass::Long => 610,
        };
        (input, output)
    }
}

/// All nine buckets in Table II's order (S-S, S-M, S-L, M-S, …, L-L).
pub fn all_buckets() -> Vec<Bucket> {
    (0..9).map(Bucket::from_index).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_match_table2_order() {
        let labels: Vec<String> = all_buckets().iter().map(|b| b.label()).collect();
        assert_eq!(
            labels,
            vec!["S-S", "S-M", "S-L", "M-S", "M-M", "M-L", "L-S", "L-M", "L-L"]
        );
    }

    #[test]
    fn index_roundtrip() {
        for i in 0..9 {
            assert_eq!(Bucket::from_index(i).index(), i);
        }
    }

    #[test]
    fn classify_representatives_identity() {
        let scheme = BucketScheme::default();
        for b in all_buckets() {
            let (i, o) = scheme.representative(b);
            assert_eq!(scheme.classify(i, o), b, "bucket {}", b.label());
        }
    }

    #[test]
    fn classify_boundaries() {
        let s = BucketScheme::default();
        assert_eq!(s.classify_input(512), LenClass::Short);
        assert_eq!(s.classify_input(513), LenClass::Medium);
        assert_eq!(s.classify_input(3073), LenClass::Long);
        assert_eq!(s.classify_output(200), LenClass::Short);
        assert_eq!(s.classify_output(481), LenClass::Long);
    }
}
