//! Workload model: requests, SLOs, the paper's Table II request-type
//! buckets, and the simulated output-length predictor.

pub mod bucket;
pub mod predictor;
pub mod request;

pub use bucket::{all_buckets, Bucket, BucketScheme, LenClass};
pub use predictor::OutputPredictor;
pub use request::{Completion, Request, RequestId, SessionRef, SloPolicy};
