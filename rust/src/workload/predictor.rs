//! Simulated output-length predictor.
//!
//! The paper (§V) simulates a DeepServe-style classifier with ~85 %
//! accuracy because production traces carry length metadata but not prompt
//! content; we do the same. With probability `accuracy` the predictor
//! returns the request's true output class; otherwise it returns one of the
//! other classes, with errors biased toward adjacent classes (a classifier
//! confuses M with S/L far more often than S with L).

use super::bucket::{Bucket, BucketScheme, LenClass};
use crate::util::json::Json;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct OutputPredictor {
    pub accuracy: f64,
    pub scheme: BucketScheme,
    rng: Pcg64,
}

impl OutputPredictor {
    pub fn new(accuracy: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&accuracy));
        OutputPredictor {
            accuracy,
            scheme: BucketScheme::default(),
            rng: Pcg64::new(seed ^ 0x9E37_79B9),
        }
    }

    /// Predict the output-length class for a request with the given true
    /// output length.
    pub fn predict_class(&mut self, true_output: usize) -> LenClass {
        let truth = self.scheme.classify_output(true_output);
        if self.rng.chance(self.accuracy) {
            return truth;
        }
        // Misprediction: adjacent class 80% of the time, far class 20%.
        match truth {
            LenClass::Short => {
                if self.rng.chance(0.8) {
                    LenClass::Medium
                } else {
                    LenClass::Long
                }
            }
            LenClass::Long => {
                if self.rng.chance(0.8) {
                    LenClass::Medium
                } else {
                    LenClass::Short
                }
            }
            LenClass::Medium => {
                if self.rng.chance(0.5) {
                    LenClass::Short
                } else {
                    LenClass::Long
                }
            }
        }
    }

    /// Predicted output length in tokens: the bucket representative of the
    /// predicted class.
    pub fn predict_tokens(&mut self, true_output: usize) -> usize {
        match self.predict_class(true_output) {
            LenClass::Short => 100,
            LenClass::Medium => 350,
            LenClass::Long => 610,
        }
    }

    /// Predict the full (input, output) bucket for a request.
    pub fn predict_bucket(&mut self, input_tokens: usize, true_output: usize) -> Bucket {
        Bucket::new(
            self.scheme.classify_input(input_tokens),
            self.predict_class(true_output),
        )
    }

    /// Bit-exact serialization for checkpoint/restore (sim::snapshot):
    /// the accuracy knob plus the exact RNG stream position, so the next
    /// prediction after restore is the one the live predictor would have
    /// drawn.
    pub fn to_snapshot(&self) -> Json {
        let (state, inc) = self.rng.state_parts();
        Json::obj()
            .set("accuracy", Json::f64_bits(self.accuracy))
            .set("rng_state", Json::u128_hex(state))
            .set("rng_inc", Json::u128_hex(inc))
    }

    /// Restore from [`OutputPredictor::to_snapshot`] output (in place; the
    /// bucket scheme is deployment config, not stream state).
    pub fn restore_snapshot(&mut self, j: &Json) -> anyhow::Result<()> {
        let accuracy = j
            .get("accuracy")
            .and_then(Json::as_f64_bits)
            .ok_or_else(|| anyhow::anyhow!("predictor snapshot: missing `accuracy`"))?;
        let state = j
            .get("rng_state")
            .and_then(Json::as_u128_hex)
            .ok_or_else(|| anyhow::anyhow!("predictor snapshot: missing `rng_state`"))?;
        let inc = j
            .get("rng_inc")
            .and_then(Json::as_u128_hex)
            .ok_or_else(|| anyhow::anyhow!("predictor snapshot: missing `rng_inc`"))?;
        self.accuracy = accuracy;
        self.rng = Pcg64::from_state_parts(state, inc);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_predictor_never_errs() {
        let mut p = OutputPredictor::new(1.0, 1);
        for out in [50, 300, 600, 1000] {
            let truth = p.scheme.classify_output(out);
            for _ in 0..50 {
                assert_eq!(p.predict_class(out), truth);
            }
        }
    }

    #[test]
    fn accuracy_is_calibrated() {
        let mut p = OutputPredictor::new(0.85, 2);
        let n = 20_000;
        let mut correct = 0;
        for i in 0..n {
            let out = [50usize, 300, 600][i % 3];
            let truth = p.scheme.classify_output(out);
            if p.predict_class(out) == truth {
                correct += 1;
            }
        }
        let acc = correct as f64 / n as f64;
        assert!((acc - 0.85).abs() < 0.02, "acc={acc}");
    }

    #[test]
    fn zero_accuracy_always_errs() {
        let mut p = OutputPredictor::new(0.0, 3);
        for _ in 0..100 {
            assert_ne!(p.predict_class(50), LenClass::Short);
        }
    }

    #[test]
    fn snapshot_restores_the_exact_prediction_stream() {
        let mut a = OutputPredictor::new(0.85, 9);
        for _ in 0..37 {
            a.predict_class(300);
        }
        let snap = a.to_snapshot();
        let mut b = OutputPredictor::new(0.85, 12345); // different stream...
        b.restore_snapshot(&snap).unwrap(); // ...until restored
        for out in [50, 300, 600, 50, 1000] {
            assert_eq!(a.predict_class(out), b.predict_class(out));
        }
    }

    #[test]
    fn mispredictions_favor_adjacent() {
        let mut p = OutputPredictor::new(0.0, 4);
        let mut med = 0;
        let mut long = 0;
        for _ in 0..10_000 {
            match p.predict_class(50) {
                LenClass::Medium => med += 1,
                LenClass::Long => long += 1,
                LenClass::Short => unreachable!(),
            }
        }
        assert!(med > 3 * long, "med={med} long={long}");
    }
}
