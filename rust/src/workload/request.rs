//! Request model shared by the trace generators, simulator, coordinator
//! and the real serving engine.

/// Unique request identifier.
pub type RequestId = u64;

/// Conversational-session membership of a request (multi-turn workloads).
///
/// `id` names the session / prefix group; `prefix_tokens` is how many of
/// the request's `input_tokens` are a re-sent prefix shared with earlier
/// turns of the same session (conversation history). An instance that
/// still holds that prefix warm in its KV cache can skip recomputing the
/// overlapping part (`sim::kvcache`). Always `prefix_tokens ≤
/// input_tokens`; first turns carry `prefix_tokens = 0`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SessionRef {
    pub id: u64,
    pub prefix_tokens: usize,
}

/// One inference request as it arrives at the gateway.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: RequestId,
    /// Arrival time in seconds from trace start.
    pub arrival: f64,
    /// Prompt length in tokens (known at arrival).
    pub input_tokens: usize,
    /// True output length in tokens (hidden from the system; revealed
    /// during generation; the predictor estimates it).
    pub output_tokens: usize,
    /// How many times this request re-entered the gateway after losing
    /// in-flight work (instance crash, preemption, aborted KVC transfer).
    /// Always 0 on arrival; bounded by the engine's retry budget.
    pub retries: u32,
    /// Session / prefix-group membership for multi-turn conversational
    /// workloads; `None` for independent one-shot requests.
    pub session: Option<SessionRef>,
}

impl Request {
    pub fn new(id: RequestId, arrival: f64, input_tokens: usize, output_tokens: usize) -> Self {
        Request {
            id,
            arrival,
            input_tokens,
            output_tokens,
            retries: 0,
            session: None,
        }
    }

    /// Attach session membership (builder style; clamps the prefix to the
    /// prompt length so the invariant holds by construction).
    pub fn with_session(mut self, session_id: u64, prefix_tokens: usize) -> Self {
        self.session = Some(SessionRef {
            id: session_id,
            prefix_tokens: prefix_tokens.min(self.input_tokens),
        });
        self
    }

    /// Total tokens this request will eventually occupy in KV cache.
    pub fn total_tokens(&self) -> usize {
        self.input_tokens + self.output_tokens
    }
}

/// Service-level objectives, following the paper's §V standards
/// (DynamoLLM-derived, MLPerf-consistent): input-length-dependent TTFT and
/// fixed 100 ms TPOT.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloPolicy {
    /// TTFT targets (seconds) for short (<256), medium (<1024) and long
    /// (≤8192-token) prompts.
    pub ttft_short_s: f64,
    pub ttft_medium_s: f64,
    pub ttft_long_s: f64,
    /// TPOT target, seconds per output token.
    pub tpot_s: f64,
}

impl Default for SloPolicy {
    fn default() -> Self {
        SloPolicy {
            ttft_short_s: 0.250,
            ttft_medium_s: 0.400,
            ttft_long_s: 2.000,
            tpot_s: 0.100,
        }
    }
}

impl SloPolicy {
    /// TTFT SLO for a given prompt length.
    pub fn ttft_slo(&self, input_tokens: usize) -> f64 {
        if input_tokens < 256 {
            self.ttft_short_s
        } else if input_tokens < 1024 {
            self.ttft_medium_s
        } else {
            self.ttft_long_s
        }
    }
}

/// Completed-request measurement produced by the simulator or the real
/// engine, consumed by the metrics subsystem.
#[derive(Clone, Copy, Debug)]
pub struct Completion {
    pub id: RequestId,
    pub arrival: f64,
    pub input_tokens: usize,
    pub output_tokens: usize,
    /// Time to first token, seconds (includes queueing + prefill + KVC
    /// transfer until the first decode step completes).
    pub ttft: f64,
    /// Mean time per output token after the first, seconds.
    pub tpot: f64,
    /// Completion wall-clock time, seconds from trace start.
    pub finish: f64,
}

impl Completion {
    /// Did this request meet both its TTFT and TPOT SLOs?
    pub fn slo_ok(&self, slo: &SloPolicy) -> bool {
        self.ttft_ok(slo) && self.tpot_ok(slo)
    }

    pub fn ttft_ok(&self, slo: &SloPolicy) -> bool {
        self.ttft <= slo.ttft_slo(self.input_tokens)
    }

    pub fn tpot_ok(&self, slo: &SloPolicy) -> bool {
        self.output_tokens <= 1 || self.tpot <= slo.tpot_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttft_slo_tiers() {
        let slo = SloPolicy::default();
        assert_eq!(slo.ttft_slo(100), 0.250);
        assert_eq!(slo.ttft_slo(256), 0.400);
        assert_eq!(slo.ttft_slo(1023), 0.400);
        assert_eq!(slo.ttft_slo(1024), 2.000);
        assert_eq!(slo.ttft_slo(8192), 2.000);
    }

    #[test]
    fn completion_slo_checks() {
        let slo = SloPolicy::default();
        let ok = Completion {
            id: 1,
            arrival: 0.0,
            input_tokens: 100,
            output_tokens: 50,
            ttft: 0.2,
            tpot: 0.05,
            finish: 3.0,
        };
        assert!(ok.slo_ok(&slo));
        let bad_ttft = Completion { ttft: 0.3, ..ok };
        assert!(!bad_ttft.slo_ok(&slo));
        let bad_tpot = Completion { tpot: 0.15, ..ok };
        assert!(!bad_tpot.slo_ok(&slo));
    }

    #[test]
    fn with_session_clamps_prefix_to_prompt() {
        let r = Request::new(1, 0.0, 100, 50).with_session(7, 500);
        assert_eq!(r.session, Some(SessionRef { id: 7, prefix_tokens: 100 }));
        let r2 = Request::new(2, 0.0, 100, 50).with_session(7, 40);
        assert_eq!(r2.session.unwrap().prefix_tokens, 40);
        assert_eq!(Request::new(3, 0.0, 10, 5).session, None);
    }

    #[test]
    fn single_token_output_ignores_tpot() {
        let slo = SloPolicy::default();
        let c = Completion {
            id: 1,
            arrival: 0.0,
            input_tokens: 100,
            output_tokens: 1,
            ttft: 0.1,
            tpot: 99.0,
            finish: 1.0,
        };
        assert!(c.slo_ok(&slo));
    }
}
