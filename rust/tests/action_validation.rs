//! Engine-side validation of control-plane actions: malformed or
//! infeasible commands must be refused with typed reasons observable in
//! metrics (and the audit log), never silently corrupt mechanics — and
//! the new action space (deflection, conversion, targeted drain,
//! convertible fleet targets) must actually work end to end.

use std::sync::Arc;
use tokenscale::perfmodel::{catalog, EngineModel};
use tokenscale::sim::{
    simulate, Action, ActionOutcome, ClusterConfig, ClusterView, ControlPlane, RejectReason, Role,
    Signal, SimConfig,
};
use tokenscale::trace::{step_trace, Trace};
use tokenscale::workload::Request;

fn engine() -> Arc<EngineModel> {
    Arc::new(EngineModel::new(
        catalog::model("llama-3.1-8b").unwrap(),
        catalog::gpu("a100-40g").unwrap(),
        1,
    ))
}

fn cluster_cfg(max_gpus: usize) -> ClusterConfig {
    ClusterConfig {
        prefill_engine: engine(),
        decode_engine: engine(),
        startup_override_s: None,
        max_gpus,
        convertible_chunk_size: 512,
        convertible_reserve_tokens: 4096.0,
        kvcache: tokenscale::sim::KvCacheConfig::disabled(),
    }
}

/// Least-loaded routing shared by the scripted policies below.
fn route_basic(signal: Signal<'_>, view: &ClusterView<'_>, actions: &mut Vec<Action>) -> bool {
    match signal {
        Signal::Arrival(req) | Signal::RetryPrefill(req) => {
            if let Some(i) = view
                .running_of(Role::Prefiller)
                .min_by_key(|i| i.inflight_prefill_tokens())
            {
                actions.push(Action::RoutePrefill {
                    req: req.id,
                    target: i.id,
                });
            }
            true
        }
        Signal::PrefillDone(req) => {
            if let Some(i) = view
                .running_of(Role::Decoder)
                .chain(view.running_of(Role::ConvertibleDecoder))
                .filter(|i| i.can_admit(req.total_tokens()))
                .min_by_key(|i| i.decode_load())
            {
                actions.push(Action::DispatchDecode {
                    req: req.id,
                    decoder: i.id,
                    bucket: 0,
                });
            }
            true
        }
        _ => false,
    }
}

#[test]
fn set_fleet_beyond_max_gpus_is_clamped_and_counted() {
    // Demands 100 prefillers + 100 decoders on a 6-GPU cluster: the
    // engine applies the quota-shared shrink and records the clamp.
    struct Greedy;
    impl ControlPlane for Greedy {
        fn name(&self) -> &str {
            "greedy"
        }
        fn on_signal(
            &mut self,
            _now: f64,
            signal: Signal<'_>,
            view: &ClusterView<'_>,
            actions: &mut Vec<Action>,
        ) {
            if route_basic(signal, view, actions) {
                return;
            }
            if matches!(signal, Signal::Tick) {
                actions.push(Action::SetFleet {
                    role: Role::Prefiller,
                    target: 100,
                });
                actions.push(Action::SetFleet {
                    role: Role::Decoder,
                    target: 100,
                });
            }
        }
    }
    let trace = step_trace(4.0, 4.0, 0.0, 0.0, 10.0, 256, 32, 31);
    let mut p = Greedy;
    let cfg = SimConfig {
        initial_prefillers: 1,
        initial_decoders: 1,
        decision_log: 128,
        ..Default::default()
    };
    let slo = cfg.slo;
    let res = simulate(cfg, cluster_cfg(6), &mut p, &trace);
    assert!(
        res.metrics.rejections.get(RejectReason::FleetOverQuota) >= 1,
        "over-quota fleet targets must be counted"
    );
    let report = res.metrics.report(&slo, 0.0);
    assert!(report.rejected_actions >= 1, "surfaced in the SLO report");
    assert!(
        report.avg_gpus <= 6.0 + 1e-9,
        "the cap held: avg {}",
        report.avg_gpus
    );
    // The audit trail shows the clamp, not a silent success.
    let log = res.decisions.expect("ring enabled");
    assert!(log.iter().any(|r| matches!(
        r.outcome,
        ActionOutcome::Clamped(RejectReason::FleetOverQuota)
    )));
    assert_eq!(res.metrics.completions.len(), trace.requests.len());
}

#[test]
fn deflect_without_reserve_capacity_is_rejected() {
    // Two big requests against one decoder: the first deflection fits,
    // the second must be refused until the first drains.
    struct DeflectAll;
    impl ControlPlane for DeflectAll {
        fn name(&self) -> &str {
            "deflect-all"
        }
        fn on_signal(
            &mut self,
            _now: f64,
            signal: Signal<'_>,
            view: &ClusterView<'_>,
            actions: &mut Vec<Action>,
        ) {
            if let Signal::Arrival(req) | Signal::RetryPrefill(req) = signal {
                if let Some(d) = view.running_of(Role::Decoder).next() {
                    actions.push(Action::DeflectPrefill {
                        req: req.id,
                        decoder: d.id,
                        chunked: true,
                    });
                }
            }
        }
    }
    let cap = engine().kv_capacity_tokens();
    let big = (cap * 0.6) as usize;
    let trace = Trace {
        name: "two-big".into(),
        duration_s: 4.0,
        requests: vec![
            Request::new(0, 0.1, big - 64, 64),
            Request::new(1, 0.2, big - 64, 64),
        ],
    };
    let mut p = DeflectAll;
    let cfg = SimConfig {
        initial_prefillers: 0,
        initial_decoders: 1,
        ..Default::default()
    };
    let res = simulate(cfg, cluster_cfg(4), &mut p, &trace);
    assert!(
        res.metrics.rejections.get(RejectReason::NoCapacity) >= 1,
        "deflection onto a decoder without reserve capacity must be rejected"
    );
    // Backpressure, not loss: both finish once memory frees up.
    assert_eq!(res.metrics.completions.len(), 2);
    assert_eq!(res.metrics.dropped, 0);
}

#[test]
fn convert_validation_and_targeted_drain() {
    // First tick: Convert the prefiller (wrong role), Convert a decoder
    // (ok), Drain the other decoder twice (second is already draining).
    // Afterwards prefills route to the freshly converted instance.
    struct ConvertScript {
        fired: bool,
    }
    impl ControlPlane for ConvertScript {
        fn name(&self) -> &str {
            "convert-script"
        }
        fn on_signal(
            &mut self,
            _now: f64,
            signal: Signal<'_>,
            view: &ClusterView<'_>,
            actions: &mut Vec<Action>,
        ) {
            match signal {
                Signal::Tick if !self.fired => {
                    self.fired = true;
                    let prefiller = view.ids_of(Role::Prefiller)[0];
                    let decoders = view.ids_of(Role::Decoder);
                    actions.push(Action::Convert { decoder: prefiller }); // wrong role
                    actions.push(Action::Convert {
                        decoder: decoders[0],
                    });
                    actions.push(Action::Drain {
                        instance: decoders[1],
                    });
                    actions.push(Action::Drain {
                        instance: decoders[1],
                    }); // already draining
                }
                Signal::Arrival(req) | Signal::RetryPrefill(req) => {
                    // Route prefill to the convertible once it exists.
                    if let Some(c) = view.running_of(Role::ConvertibleDecoder).next() {
                        actions.push(Action::RoutePrefill {
                            req: req.id,
                            target: c.id,
                        });
                    }
                }
                _ => {}
            }
        }
    }
    let trace = step_trace(2.0, 2.0, 0.0, 0.0, 10.0, 256, 32, 33);
    let mut p = ConvertScript { fired: false };
    let cfg = SimConfig {
        initial_prefillers: 1,
        initial_decoders: 2,
        decision_log: 64,
        ..Default::default()
    };
    let res = simulate(cfg, cluster_cfg(8), &mut p, &trace);
    assert_eq!(res.metrics.rejections.get(RejectReason::WrongRole), 1);
    assert_eq!(res.metrics.rejections.get(RejectReason::AlreadyDraining), 1);
    assert!(res.scale_downs >= 1, "targeted drain counts as a scale-down");
    let log = res.decisions.expect("ring enabled");
    assert!(log.iter().any(|r| matches!(
        (r.action, r.outcome),
        (Action::Convert { .. }, ActionOutcome::Applied)
    )));
    // The converted instance serves the whole workload in place.
    assert_eq!(res.metrics.completions.len(), trace.requests.len());
}

#[test]
fn unchunked_deflection_completes_through_decode() {
    // No prefillers at all: every prompt runs as a single restricted-
    // chunked pass on the lone decoder, then decodes there.
    struct DeflectUnchunked;
    impl ControlPlane for DeflectUnchunked {
        fn name(&self) -> &str {
            "deflect-unchunked"
        }
        fn on_signal(
            &mut self,
            _now: f64,
            signal: Signal<'_>,
            view: &ClusterView<'_>,
            actions: &mut Vec<Action>,
        ) {
            if let Signal::Arrival(req) | Signal::RetryPrefill(req) = signal {
                if let Some(d) = view
                    .running_of(Role::Decoder)
                    .filter(|d| d.admission_capacity() >= req.total_tokens() as f64)
                    .min_by_key(|d| d.decode_load())
                {
                    actions.push(Action::DeflectPrefill {
                        req: req.id,
                        decoder: d.id,
                        chunked: false,
                    });
                }
            }
        }
    }
    let trace = step_trace(2.0, 2.0, 0.0, 0.0, 10.0, 512, 32, 35);
    let mut p = DeflectUnchunked;
    let cfg = SimConfig {
        initial_prefillers: 0,
        initial_decoders: 1,
        ..Default::default()
    };
    let res = simulate(cfg, cluster_cfg(4), &mut p, &trace);
    assert_eq!(res.metrics.completions.len(), trace.requests.len());
    for c in &res.metrics.completions {
        assert!(c.ttft > 0.0 && c.ttft.is_finite());
    }
    assert_eq!(res.metrics.rejections.total(), 0);
}

#[test]
fn convertible_fleet_target_spawns_pool() {
    // SetFleet for the convertible role provisions the pool; prefills
    // queue until the convertible finishes starting, then run in place.
    struct ConvPool;
    impl ControlPlane for ConvPool {
        fn name(&self) -> &str {
            "conv-pool"
        }
        fn on_signal(
            &mut self,
            _now: f64,
            signal: Signal<'_>,
            view: &ClusterView<'_>,
            actions: &mut Vec<Action>,
        ) {
            match signal {
                Signal::Tick => {
                    actions.push(Action::SetFleet {
                        role: Role::ConvertibleDecoder,
                        target: 1,
                    });
                }
                Signal::Arrival(req) | Signal::RetryPrefill(req) => {
                    if let Some(c) = view.running_of(Role::ConvertibleDecoder).next() {
                        actions.push(Action::RoutePrefill {
                            req: req.id,
                            target: c.id,
                        });
                    }
                }
                _ => {}
            }
        }
    }
    let trace = step_trace(1.0, 1.0, 0.0, 0.0, 12.0, 256, 16, 37);
    let mut p = ConvPool;
    let cfg = SimConfig {
        initial_prefillers: 0,
        initial_decoders: 0,
        initial_convertibles: 0,
        ..Default::default()
    };
    let res = simulate(cfg, cluster_cfg(4), &mut p, &trace);
    assert!(res.scale_ups >= 1, "convertible pool spawned");
    assert_eq!(res.metrics.completions.len(), trace.requests.len());
}

#[test]
fn misaddressed_routing_actions_are_rejected() {
    // Routing actions that name the wrong request, or route twice, are
    // refused; the request still completes via the fallback queue/retry.
    struct Confused {
        tried_bad: bool,
    }
    impl ControlPlane for Confused {
        fn name(&self) -> &str {
            "confused"
        }
        fn on_signal(
            &mut self,
            _now: f64,
            signal: Signal<'_>,
            view: &ClusterView<'_>,
            actions: &mut Vec<Action>,
        ) {
            match signal {
                Signal::Arrival(req) | Signal::RetryPrefill(req) => {
                    let target = view.running_of(Role::Prefiller).next().unwrap().id;
                    if !self.tried_bad {
                        self.tried_bad = true;
                        // Wrong request id: rejected, request queues.
                        actions.push(Action::RoutePrefill {
                            req: req.id + 1_000_000,
                            target,
                        });
                    } else {
                        actions.push(Action::RoutePrefill { req: req.id, target });
                        // Second routing for the same request: rejected.
                        actions.push(Action::RoutePrefill { req: req.id, target });
                    }
                }
                Signal::PrefillDone(req) => {
                    if let Some(i) = view
                        .running_of(Role::Decoder)
                        .filter(|i| i.can_admit(req.total_tokens()))
                        .min_by_key(|i| i.decode_load())
                    {
                        actions.push(Action::DispatchDecode {
                            req: req.id,
                            decoder: i.id,
                            bucket: 0,
                        });
                    }
                }
                _ => {}
            }
        }
    }
    let trace = step_trace(2.0, 2.0, 0.0, 0.0, 6.0, 128, 16, 39);
    let mut p = Confused { tried_bad: false };
    let cfg = SimConfig {
        initial_prefillers: 1,
        initial_decoders: 1,
        ..Default::default()
    };
    let res = simulate(cfg, cluster_cfg(4), &mut p, &trace);
    assert!(res.metrics.rejections.get(RejectReason::UnknownRequest) >= 1);
    assert!(res.metrics.rejections.get(RejectReason::DuplicateRoute) >= 1);
    assert_eq!(res.metrics.completions.len(), trace.requests.len());
}
