//! The v1→v2 control-plane redesign equivalence gate.
//!
//! The action-based ControlPlane v2 API replaced the old `Coordinator`
//! trait; the pre-redesign engine loop is frozen in `sim::legacy` for one
//! PR exactly so this test can prove the swap changed *nothing* about the
//! results: every policy is built once through the registry, then driven
//!
//! - through the frozen v1 engine (via `V1Bridge`, which reproduces the
//!   old observe/route/scale/predict call pattern), and
//! - through the v2 signal/action engine,
//!
//! and the two runs must agree **bit for bit**: every `SloReport` field
//! (attainments, GPU cost, every latency percentile), every completion,
//! the event count and the scaling activity. Scenarios cover the fig6-
//! style policy-compare smoke (Mixed @ 22 RPS on `small-a100`) and both
//! `fig_longtrace --smoke` scenario shapes (diurnal Azure-Conversation
//! and burst-injected Mixed on `large-a100`), for TokenScale and all
//! three baselines.

use tokenscale::metrics::SloReport;
use tokenscale::report::runner::{
    run_experiment_legacy, run_experiment_source_legacy, RunOverrides,
};
use tokenscale::report::{
    deployment, run_experiment, run_experiment_source, ExperimentResult, PolicyKind,
};
use tokenscale::trace::{
    generate_family, ArrivalSource, BurstWindow, MixedSource, SourceExt, SpecSource, TraceFamily,
};
use tokenscale::util::stats::Summary;

/// Every pre-redesign `SloReport` field, bit-exact (f64s via `to_bits`).
fn report_bits(r: &SloReport) -> Vec<u64> {
    let mut out = vec![
        r.n as u64,
        r.ttft_attainment.to_bits(),
        r.tpot_attainment.to_bits(),
        r.overall_attainment.to_bits(),
        r.avg_gpus.to_bits(),
    ];
    let mut push_summary = |s: &Summary| {
        out.push(s.count as u64);
        out.push(s.mean.to_bits());
        out.push(s.p50.to_bits());
        out.push(s.p90.to_bits());
        out.push(s.p99.to_bits());
        out.push(s.max.to_bits());
    };
    push_summary(&r.ttft);
    push_summary(&r.tpot);
    push_summary(&r.prefill_wait);
    push_summary(&r.queue_wait);
    out
}

fn completion_bits(res: &ExperimentResult) -> Vec<(u64, u64, u64, u64, u64)> {
    res.sim
        .metrics
        .completions
        .iter()
        .map(|c| {
            (
                c.id,
                c.arrival.to_bits(),
                c.ttft.to_bits(),
                c.tpot.to_bits(),
                c.finish.to_bits(),
            )
        })
        .collect()
}

fn assert_equivalent(label: &str, v1: &ExperimentResult, v2: &ExperimentResult) {
    assert_eq!(
        report_bits(&v1.report),
        report_bits(&v2.report),
        "{label}: SloReport must be byte-identical across the redesign"
    );
    assert_eq!(
        completion_bits(v1),
        completion_bits(v2),
        "{label}: completions must be identical"
    );
    assert_eq!(
        v1.sim.events_processed, v2.sim.events_processed,
        "{label}: event counts must match"
    );
    assert_eq!(v1.sim.scale_ups, v2.sim.scale_ups, "{label}: scale-ups");
    assert_eq!(v1.sim.scale_downs, v2.sim.scale_downs, "{label}: scale-downs");
    assert_eq!(
        v1.sim.metrics.gpu_seconds.to_bits(),
        v2.sim.metrics.gpu_seconds.to_bits(),
        "{label}: GPU-seconds (cost) must be bit-identical"
    );
    // The ported policies only emit actions the engine accepts, so the
    // "0.0 delta" claim holds with zero rejections on the v2 path too.
    assert_eq!(
        v2.sim.metrics.rejections.total(),
        0,
        "{label}: stock policies must have no rejected actions"
    );
    assert!(v2.report.n > 0, "{label}: scenario must complete requests");
}

/// Fig. 6/9-style policy-compare smoke: the bursty Mixed family at the
/// paper's 22 RPS on the 16-GPU `small-a100` preset.
#[test]
fn policy_compare_smoke_is_bit_identical_across_redesign() {
    let dep = deployment("small-a100").unwrap();
    let trace = generate_family(TraceFamily::Mixed, 22.0, 90.0, 42);
    let ov = RunOverrides::default();
    for policy in PolicyKind::all_baselines() {
        let v1 = run_experiment_legacy(&dep, policy, &trace, &ov);
        let v2 = run_experiment(&dep, policy, &trace, &ov);
        assert_equivalent(&format!("fig6-compare/{}", policy.name()), &v1, &v2);
    }
}

fn diurnal_source(duration: f64, rps: f64) -> Box<dyn ArrivalSource + Send> {
    // Same shape as fig_longtrace's "diurnal-conv" scenario (smoke scale).
    let amp = 0.35;
    SpecSource::new(TraceFamily::AzureConv.spec(rps * (1.0 + amp), duration), 101)
        .diurnal(amp, duration, 202)
        .boxed()
}

fn burst_source(duration: f64, rps: f64) -> Box<dyn ArrivalSource + Send> {
    // Same shape as fig_longtrace's "burst-mixed" scenario (smoke scale).
    let bursts: Vec<BurstWindow> = (0..3)
        .map(|i| BurstWindow::new(duration * (0.15 + 0.25 * i as f64), duration * 0.05, 3.0))
        .collect();
    MixedSource::new(rps, duration, 303)
        .inject_bursts(bursts, 404)
        .boxed()
}

fn longtrace_scenario(label: &str, make: &dyn Fn() -> Box<dyn ArrivalSource + Send>) {
    let dep = deployment("large-a100").unwrap();
    let ov = RunOverrides::default();
    for policy in PolicyKind::all_baselines() {
        let mut src1 = make();
        let profile = src1.profile();
        let v1 = run_experiment_source_legacy(&dep, policy, src1.as_mut(), &profile, &ov);
        let mut src2 = make();
        let v2 = run_experiment_source(&dep, policy, src2.as_mut(), &profile, &ov);
        assert_equivalent(&format!("{label}/{}", policy.name()), &v1, &v2);
    }
}

#[test]
fn longtrace_diurnal_smoke_is_bit_identical_across_redesign() {
    longtrace_scenario("longtrace-diurnal", &|| diurnal_source(150.0, 5.0));
}

#[test]
fn longtrace_burst_smoke_is_bit_identical_across_redesign() {
    longtrace_scenario("longtrace-burst", &|| burst_source(150.0, 5.0));
}
