//! Determinism gate for the action-based control-plane engine.
//!
//! The v1→v2 redesign shipped with a frozen `sim::legacy` oracle proving
//! the swap was bit-identical; that oracle (and its test leg) was deleted
//! one PR later as scheduled. What survives is the part of the contract
//! that must keep holding: for every stock policy, the same scenario run
//! twice — through the declarative [`Scenario`] layer, the way every
//! suite cell runs — produces **bit-identical** results: every `SloReport`
//! field (attainments, GPU cost, every latency percentile), every
//! completion, the event count, the scaling activity, and zero rejected
//! actions.
//!
//! Scenarios cover the fig6/9-style policy-compare smoke (Mixed @ 22 RPS
//! on `small-a100`) and both `fig_longtrace --smoke` scenario shapes
//! (diurnal Azure-Conversation and burst-injected Mixed on `large-a100`).

use tokenscale::metrics::SloReport;
use tokenscale::report::{
    run_experiment, ExperimentResult, Scenario, TransformStep, WorkloadSpec,
};
use tokenscale::trace::{BurstWindow, TraceFamily};
use tokenscale::util::stats::Summary;

/// Every `SloReport` field, bit-exact (f64s via `to_bits`).
fn report_bits(r: &SloReport) -> Vec<u64> {
    let mut out = vec![
        r.n as u64,
        r.ttft_attainment.to_bits(),
        r.tpot_attainment.to_bits(),
        r.overall_attainment.to_bits(),
        r.avg_gpus.to_bits(),
    ];
    let mut push_summary = |s: &Summary| {
        out.push(s.count as u64);
        out.push(s.mean.to_bits());
        out.push(s.p50.to_bits());
        out.push(s.p90.to_bits());
        out.push(s.p99.to_bits());
        out.push(s.max.to_bits());
    };
    push_summary(&r.ttft);
    push_summary(&r.tpot);
    push_summary(&r.prefill_wait);
    push_summary(&r.queue_wait);
    out
}

fn completion_bits(res: &ExperimentResult) -> Vec<(u64, u64, u64, u64, u64)> {
    res.sim
        .metrics
        .completions
        .iter()
        .map(|c| {
            (
                c.id,
                c.arrival.to_bits(),
                c.ttft.to_bits(),
                c.tpot.to_bits(),
                c.finish.to_bits(),
            )
        })
        .collect()
}

fn assert_deterministic(label: &str, a: &ExperimentResult, b: &ExperimentResult) {
    assert_eq!(
        report_bits(&a.report),
        report_bits(&b.report),
        "{label}: SloReport must be byte-identical across repeated runs"
    );
    assert_eq!(
        completion_bits(a),
        completion_bits(b),
        "{label}: completions must be identical"
    );
    assert_eq!(
        a.sim.events_processed, b.sim.events_processed,
        "{label}: event counts must match"
    );
    assert_eq!(a.sim.scale_ups, b.sim.scale_ups, "{label}: scale-ups");
    assert_eq!(a.sim.scale_downs, b.sim.scale_downs, "{label}: scale-downs");
    assert_eq!(
        a.sim.metrics.gpu_seconds.to_bits(),
        b.sim.metrics.gpu_seconds.to_bits(),
        "{label}: GPU-seconds (cost) must be bit-identical"
    );
    // Stock policies only emit actions the engine accepts.
    assert_eq!(
        a.sim.metrics.rejections.total(),
        0,
        "{label}: stock policies must have no rejected actions"
    );
    assert!(a.report.n > 0, "{label}: scenario must complete requests");
}

/// Run every (policy) cell of the scenario twice through freshly compiled
/// specs — independent source factories, independent policy instances —
/// and require bit equality.
fn scenario_is_deterministic(scenario: &Scenario) {
    let first = scenario.experiment_specs().expect("specs compile");
    let second = scenario.experiment_specs().expect("specs compile");
    for (sa, sb) in first.iter().zip(&second) {
        let a = run_experiment(sa);
        let b = run_experiment(sb);
        assert_deterministic(&sa.label, &a, &b);
    }
}

/// Fig. 6/9-style policy-compare smoke: the bursty Mixed family at the
/// paper's 22 RPS on the 16-GPU `small-a100` preset, shared-trace mode.
#[test]
fn policy_compare_smoke_is_bit_deterministic() {
    let scenario = Scenario::new(
        "fig6-compare",
        "small-a100",
        WorkloadSpec::Synthetic {
            family: TraceFamily::Mixed,
            rps: 22.0,
            duration_s: 90.0,
            seed: 42,
        },
    )
    .all_baselines()
    .materialized();
    scenario_is_deterministic(&scenario);
}

/// `fig_longtrace`'s "diurnal-conv" shape at smoke scale, streaming mode.
#[test]
fn longtrace_diurnal_smoke_is_bit_deterministic() {
    let (duration, rps, amp) = (150.0, 5.0, 0.35);
    let scenario = Scenario::new(
        "longtrace-diurnal",
        "large-a100",
        WorkloadSpec::Synthetic {
            family: TraceFamily::AzureConv,
            rps: rps * (1.0 + amp),
            duration_s: duration,
            seed: 101,
        },
    )
    .transform(TransformStep::Diurnal {
        amplitude: amp,
        period_s: duration,
        seed: 202,
    })
    .all_baselines();
    scenario_is_deterministic(&scenario);
}

/// `fig_longtrace`'s "burst-mixed" shape at smoke scale, streaming mode.
#[test]
fn longtrace_burst_smoke_is_bit_deterministic() {
    let duration = 150.0;
    let bursts: Vec<BurstWindow> = (0..3)
        .map(|i| BurstWindow::new(duration * (0.15 + 0.25 * i as f64), duration * 0.05, 3.0))
        .collect();
    let scenario = Scenario::new(
        "longtrace-burst",
        "large-a100",
        WorkloadSpec::Synthetic {
            family: TraceFamily::Mixed,
            rps: 5.0,
            duration_s: duration,
            seed: 303,
        },
    )
    .transform(TransformStep::Burst {
        windows: bursts,
        seed: 404,
    })
    .all_baselines();
    scenario_is_deterministic(&scenario);
}

/// Shared-trace and streaming modes agree when driven from the same
/// measured workload profile: the scenario layer's `materialize` switch
/// changes memory behavior, not results.
#[test]
fn materialized_and_streamed_scenarios_agree_on_measured_profile() {
    use tokenscale::trace::TraceProfile;

    let base = Scenario::new(
        "mode-agreement",
        "small-a100",
        WorkloadSpec::Synthetic {
            family: TraceFamily::AzureConv,
            rps: 8.0,
            duration_s: 60.0,
            seed: 31,
        },
    )
    .policies(&["tokenscale", "distserve"]);

    let trace = base.build_trace().expect("materialize");
    let profile = TraceProfile::of_trace(&trace);
    let shared_specs = base.clone().materialized().experiment_specs().unwrap();
    let streamed_specs = base.experiment_specs().unwrap();
    for (shared, streamed) in shared_specs.iter().zip(&streamed_specs) {
        let a = run_experiment(shared);
        // Pin the streamed cell to the measured profile so the only
        // difference is preloaded-vs-streamed arrival delivery.
        let b = run_experiment(&streamed.clone().with_profile(profile));
        assert_deterministic(&shared.label, &a, &b);
    }
}
