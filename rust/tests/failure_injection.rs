//! Failure injection and edge cases: the system must degrade gracefully,
//! never deadlock, and account for everything it drops.

use std::sync::Arc;
use tokenscale::metrics::DropReason;
use tokenscale::perfmodel::{catalog, EngineModel};
use tokenscale::report::runner::RunOverrides;
use tokenscale::report::{deployment, run_experiment, ExperimentSpec, PolicyKind};
use tokenscale::sim::{
    simulate, ClusterConfig, FaultKind, FaultPlan, FaultSchedule, FaultSpec, Role, SimConfig,
    StaticCoordinator,
};
use tokenscale::trace::{step_trace, Trace};
use tokenscale::workload::Request;

fn engine() -> Arc<EngineModel> {
    Arc::new(EngineModel::new(
        catalog::model("llama-3.1-8b").unwrap(),
        catalog::gpu("a100-40g").unwrap(),
        1,
    ))
}

fn cluster_cfg(max_gpus: usize) -> ClusterConfig {
    ClusterConfig {
        prefill_engine: engine(),
        decode_engine: engine(),
        startup_override_s: None,
        max_gpus,
        convertible_chunk_size: 512,
        convertible_reserve_tokens: 4096.0,
        kvcache: tokenscale::sim::KvCacheConfig::disabled(),
    }
}

#[test]
fn empty_trace_completes_instantly() {
    let trace = Trace {
        name: "empty".into(),
        duration_s: 10.0,
        requests: vec![],
    };
    let mut coord = StaticCoordinator::new(1, 1);
    let res = simulate(SimConfig::default(), cluster_cfg(4), &mut coord, &trace);
    assert_eq!(res.metrics.completions.len(), 0);
    assert_eq!(res.metrics.dropped, 0);
}

#[test]
fn oversized_request_is_rejected_not_deadlocked() {
    // A request whose KV footprint exceeds a whole decoder is rejected and
    // accounted; everything else still completes.
    let cap_tokens = engine().kv_capacity_tokens() as usize;
    let mut requests = vec![
        Request::new(0, 0.1, 256, 64),
        Request::new(1, 0.2, 8192, cap_tokens), // impossible
        Request::new(2, 0.3, 256, 64),
    ];
    requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    let trace = Trace {
        name: "oversized".into(),
        duration_s: 5.0,
        requests,
    };
    let mut coord = StaticCoordinator::new(1, 1);
    let res = simulate(SimConfig::default(), cluster_cfg(4), &mut coord, &trace);
    assert_eq!(res.metrics.dropped, 1, "oversized request must be dropped");
    assert_eq!(res.metrics.completions.len(), 2, "others must complete");
}

#[test]
fn simultaneous_arrivals_are_handled() {
    let requests: Vec<Request> = (0..50)
        .map(|i| Request::new(i, 1.0, 128, 16))
        .collect();
    let trace = Trace {
        name: "thundering-herd".into(),
        duration_s: 5.0,
        requests,
    };
    let mut coord = StaticCoordinator::new(2, 2);
    let cfg = SimConfig {
        initial_prefillers: 2,
        initial_decoders: 2,
        ..Default::default()
    };
    let res = simulate(cfg, cluster_cfg(8), &mut coord, &trace);
    assert_eq!(res.metrics.completions.len(), 50);
}

#[test]
fn tiny_gpu_cap_still_serves_with_degraded_slo() {
    // Cap of 2 GPUs: the autoscaler wants more but can't have them.
    let dep = deployment("small-a100").unwrap();
    let trace = step_trace(16.0, 16.0, 0.0, 0.0, 30.0, 1024, 128, 3); // 2x one prefiller's V_P
    let mut dep2 = dep.clone();
    dep2.max_gpus = 2;
    dep2.initial_prefillers = 1;
    dep2.initial_decoders = 1;
    let res = run_experiment(
        &ExperimentSpec::shared(&dep2, PolicyKind::named("tokenscale"), &trace).with_overrides(
            RunOverrides {
                convertibles: Some(0),
                warmup_s: 0.0,
                ..Default::default()
            },
        ),
    );
    // Overload: most requests finish (eventually) and none vanish.
    assert!(res.report.n + res.sim.metrics.dropped > 0);
    assert!(
        res.report.overall_attainment < 0.9,
        "a 2-GPU cluster can't meet SLOs at this load (got {})",
        res.report.overall_attainment
    );
}

#[test]
fn zero_output_predictor_accuracy_still_works() {
    let dep = deployment("small-a100").unwrap();
    let trace = step_trace(6.0, 6.0, 0.0, 0.0, 30.0, 512, 128, 5);
    let res = run_experiment(
        &ExperimentSpec::shared(&dep, PolicyKind::named("tokenscale"), &trace).with_overrides(
            RunOverrides {
                predictor_accuracy: Some(0.0),
                warmup_s: 0.0,
                ..Default::default()
            },
        ),
    );
    // Always-wrong predictions cost efficiency, never correctness.
    assert_eq!(res.report.n, trace.requests.len());
}

#[test]
fn draining_prefiller_finishes_queue() {
    // Scale down mid-burst: requests already queued on the retired
    // prefiller must still complete.
    use tokenscale::sim::{Action, ClusterView, ControlPlane, Role, Signal};

    struct ShrinkAt {
        t: f64,
    }
    impl ControlPlane for ShrinkAt {
        fn name(&self) -> &str {
            "shrink"
        }
        fn on_signal(
            &mut self,
            now: f64,
            signal: Signal<'_>,
            view: &ClusterView<'_>,
            actions: &mut Vec<Action>,
        ) {
            match signal {
                Signal::Arrival(req) | Signal::RetryPrefill(req) => {
                    if let Some(i) = view
                        .running_of(Role::Prefiller)
                        .min_by_key(|i| i.inflight_prefill_tokens())
                    {
                        actions.push(Action::RoutePrefill {
                            req: req.id,
                            target: i.id,
                        });
                    }
                }
                Signal::PrefillDone(req) => {
                    if let Some(i) = view
                        .running_of(Role::Decoder)
                        .filter(|i| i.can_admit(req.total_tokens()))
                        .min_by_key(|i| i.decode_load())
                    {
                        actions.push(Action::DispatchDecode {
                            req: req.id,
                            decoder: i.id,
                            bucket: 0,
                        });
                    }
                }
                Signal::Tick => {
                    actions.push(Action::SetFleet {
                        role: Role::Prefiller,
                        target: if now >= self.t { 1 } else { 3 },
                    });
                    actions.push(Action::SetFleet {
                        role: Role::Decoder,
                        target: 2,
                    });
                }
                _ => {}
            }
        }
    }

    let trace = step_trace(10.0, 10.0, 0.0, 0.0, 20.0, 1024, 32, 7);
    let mut coord = ShrinkAt { t: 5.0 };
    let cfg = SimConfig {
        initial_prefillers: 3,
        initial_decoders: 2,
        ..Default::default()
    };
    let res = simulate(cfg, cluster_cfg(8), &mut coord, &trace);
    assert_eq!(
        res.metrics.completions.len(),
        trace.requests.len(),
        "scale-down dropped requests"
    );
    assert!(res.scale_downs >= 2);
}

// ---------------------------------------- sim::faults mechanics

/// A run with no fault plan must report an all-zero failure ledger, and
/// goodput must collapse to plain SLO attainment.
#[test]
fn fault_free_run_has_zero_ledger() {
    let trace = step_trace(6.0, 6.0, 0.0, 0.0, 20.0, 512, 64, 11);
    let mut coord = StaticCoordinator::new(2, 2);
    let cfg = SimConfig {
        initial_prefillers: 2,
        initial_decoders: 2,
        ..Default::default()
    };
    let slo = cfg.slo;
    let res = simulate(cfg, cluster_cfg(8), &mut coord, &trace);
    let r = res.metrics.report(&slo, 0.0);
    assert_eq!(res.metrics.completions.len(), trace.requests.len());
    assert_eq!(r.faults_injected, 0);
    assert_eq!(r.lost_requests, 0);
    assert_eq!(r.retried_requests, 0);
    assert_eq!(r.abandoned_requests, 0);
    assert_eq!(r.transfer_retries, 0);
    assert_eq!(r.transfer_aborts, 0);
    assert_eq!(r.recovery_events, 0);
    assert_eq!(r.wasted_prefill_tokens, 0.0);
    assert_eq!(
        r.goodput_attainment.to_bits(),
        r.overall_attainment.to_bits(),
        "with nothing abandoned, goodput == attainment"
    );
}

/// A decoder crash destroys in-flight decode work; the victims re-enter
/// the gateway, are re-prefilled (wasted tokens), and — with the static
/// fleet restoring capacity — everything is eventually served or typed.
#[test]
fn decoder_crash_displaces_work_and_requeues() {
    let trace = step_trace(6.0, 6.0, 0.0, 0.0, 25.0, 512, 256, 9);
    let mut coord = StaticCoordinator::new(2, 2);
    let cfg = SimConfig {
        initial_prefillers: 2,
        initial_decoders: 2,
        faults: FaultPlan {
            seed: 7,
            entries: vec![FaultSpec {
                kind: FaultKind::Crash,
                role: Some(Role::Decoder),
                instance_index: None,
                schedule: FaultSchedule::At { t: 8.0 },
            }],
        },
        ..Default::default()
    };
    let slo = cfg.slo;
    let res = simulate(cfg, cluster_cfg(8), &mut coord, &trace);
    let r = res.metrics.report(&slo, 0.0);
    assert!(r.faults_injected >= 1, "the crash must land");
    assert!(
        r.lost_requests >= 1,
        "a busy decoder must hold in-flight work at t=8"
    );
    assert!(r.retried_requests >= 1, "victims must re-enter the gateway");
    assert!(
        r.wasted_prefill_tokens > 0.0,
        "re-prefilling victims costs tokens"
    );
    assert_eq!(
        res.metrics.completions.len() + res.metrics.abandoned.len() + res.metrics.dropped,
        trace.requests.len(),
        "every request must be accounted for"
    );
    assert!(
        !res.metrics.recoveries.is_empty(),
        "salvaging the victims must record a recovery time"
    );
}

/// A mid-run transfer brownout forces timeouts, backoff retries and
/// re-prefill fallbacks — but once the window closes, everything still
/// completes.
#[test]
fn transfer_brownout_retries_then_recovers() {
    let trace = step_trace(4.0, 4.0, 0.0, 0.0, 20.0, 512, 64, 23);
    let mut coord = StaticCoordinator::new(1, 1);
    let cfg = SimConfig {
        faults: FaultPlan {
            seed: 17,
            entries: vec![FaultSpec {
                kind: FaultKind::Transfer {
                    loss_prob: 1.0,
                    stall_s: 1.0,
                    max_retries: 1,
                    duration_s: 6.0,
                },
                role: None,
                instance_index: None,
                schedule: FaultSchedule::At { t: 5.0 },
            }],
        },
        ..Default::default()
    };
    let slo = cfg.slo;
    let res = simulate(cfg, cluster_cfg(4), &mut coord, &trace);
    let r = res.metrics.report(&slo, 0.0);
    assert!(r.transfer_retries >= 1, "lost transfers must be retried");
    assert!(
        r.transfer_aborts >= 1,
        "with loss_prob=1 inside the window, the retry budget must run dry"
    );
    assert!(
        r.wasted_prefill_tokens > 0.0,
        "aborted transfers fall back to re-prefill"
    );
    assert_eq!(
        res.metrics.completions.len(),
        trace.requests.len(),
        "the brownout is transient: everything completes after the window"
    );
}

/// A permanent transfer blackout exhausts each request's retry budget:
/// the gateway must abandon them with a typed reason instead of cycling
/// forever (the requeue-forever hazard).
#[test]
fn retry_budget_exhaustion_abandons_typed() {
    let trace = step_trace(2.0, 2.0, 0.0, 0.0, 4.0, 256, 32, 5);
    let mut coord = StaticCoordinator::new(1, 1);
    let cfg = SimConfig {
        faults: FaultPlan {
            seed: 3,
            entries: vec![FaultSpec {
                kind: FaultKind::Transfer {
                    loss_prob: 1.0,
                    stall_s: 0.5,
                    max_retries: 0,
                    duration_s: 10_000.0,
                },
                role: None,
                instance_index: None,
                schedule: FaultSchedule::At { t: 0.0 },
            }],
        },
        ..Default::default()
    };
    let retry_limit = cfg.retry_limit;
    let slo = cfg.slo;
    let res = simulate(cfg, cluster_cfg(4), &mut coord, &trace);
    let r = res.metrics.report(&slo, 0.0);
    assert_eq!(
        res.metrics.completions.len(),
        0,
        "no transfer can ever succeed"
    );
    assert_eq!(
        res.metrics.abandoned.len(),
        trace.requests.len(),
        "every request must be abandoned, not stuck"
    );
    for a in &res.metrics.abandoned {
        assert_eq!(a.reason, DropReason::RetryBudget);
        assert!(
            a.retries >= retry_limit,
            "the budget must actually be consumed (got {})",
            a.retries
        );
    }
    assert_eq!(r.abandoned_retry_budget, trace.requests.len());
    assert_eq!(r.retried_requests, trace.requests.len());
    assert_eq!(
        r.goodput_attainment, 0.0,
        "goodput charges the abandoned offered load"
    );
}

/// A degraded (straggler) prefiller slows TTFT for the window and then
/// restores — it never drops work.
#[test]
fn degraded_prefiller_slows_then_restores() {
    let trace = step_trace(4.0, 4.0, 0.0, 0.0, 30.0, 1024, 32, 21);
    let base_cfg = SimConfig::default();
    let slo = base_cfg.slo;
    let mut coord = StaticCoordinator::new(1, 1);
    let base = simulate(base_cfg, cluster_cfg(4), &mut coord, &trace);
    let r_base = base.metrics.report(&slo, 0.0);

    let cfg = SimConfig {
        faults: FaultPlan {
            seed: 29,
            entries: vec![FaultSpec {
                kind: FaultKind::Degrade {
                    factor: 5.0,
                    duration_s: 15.0,
                },
                role: Some(Role::Prefiller),
                instance_index: Some(0),
                schedule: FaultSchedule::At { t: 5.0 },
            }],
        },
        ..Default::default()
    };
    let mut coord = StaticCoordinator::new(1, 1);
    let deg = simulate(cfg, cluster_cfg(4), &mut coord, &trace);
    let r_deg = deg.metrics.report(&slo, 0.0);

    assert!(r_deg.faults_injected >= 1, "the degrade must land");
    assert_eq!(
        deg.metrics.completions.len(),
        trace.requests.len(),
        "degradation slows, never drops"
    );
    assert!(
        r_deg.ttft.mean > r_base.ttft.mean,
        "a 5x-slow prefiller must hurt TTFT ({} <= {})",
        r_deg.ttft.mean,
        r_base.ttft.mean
    );
}

/// When the decode pool collapses for good, requests parked awaiting
/// decode must drain through the starvation bound as typed drops — the
/// simulation must terminate, not spin.
#[test]
fn decode_pool_collapse_starves_typed() {
    use tokenscale::sim::{Action, ClusterView, ControlPlane, Signal};

    /// Routes normally but retires the whole decode pool at t >= 5 and
    /// never brings it back.
    struct KillDecode;
    impl ControlPlane for KillDecode {
        fn name(&self) -> &str {
            "kill-decode"
        }
        fn on_signal(
            &mut self,
            now: f64,
            signal: Signal<'_>,
            view: &ClusterView<'_>,
            actions: &mut Vec<Action>,
        ) {
            match signal {
                Signal::Arrival(req) | Signal::RetryPrefill(req) => {
                    if let Some(i) = view
                        .running_of(Role::Prefiller)
                        .min_by_key(|i| i.inflight_prefill_tokens())
                    {
                        actions.push(Action::RoutePrefill {
                            req: req.id,
                            target: i.id,
                        });
                    }
                }
                Signal::PrefillDone(req) => {
                    if let Some(i) = view
                        .running_of(Role::Decoder)
                        .filter(|i| i.can_admit(req.total_tokens()))
                        .min_by_key(|i| i.decode_load())
                    {
                        actions.push(Action::DispatchDecode {
                            req: req.id,
                            decoder: i.id,
                            bucket: 0,
                        });
                    }
                }
                Signal::Tick => {
                    actions.push(Action::SetFleet {
                        role: Role::Prefiller,
                        target: 1,
                    });
                    actions.push(Action::SetFleet {
                        role: Role::Decoder,
                        target: if now >= 5.0 { 0 } else { 1 },
                    });
                }
                _ => {}
            }
        }
    }

    let trace = step_trace(4.0, 4.0, 0.0, 0.0, 15.0, 256, 64, 31);
    let mut coord = KillDecode;
    let cfg = SimConfig {
        starvation_age_s: 3.0,
        ..Default::default()
    };
    let slo = cfg.slo;
    let res = simulate(cfg, cluster_cfg(4), &mut coord, &trace);
    let r = res.metrics.report(&slo, 0.0);
    assert!(
        !res.metrics.completions.is_empty(),
        "work served before the collapse must complete"
    );
    assert!(r.abandoned_starved >= 1, "the starvation bound must fire");
    assert!(
        res.metrics
            .abandoned
            .iter()
            .all(|a| a.reason == DropReason::Starved),
        "collapse drops are starvation, not retry-budget"
    );
    assert_eq!(
        res.metrics.completions.len() + res.metrics.abandoned.len() + res.metrics.dropped,
        trace.requests.len(),
        "every request must be accounted for"
    );
}
