//! Failure injection and edge cases: the system must degrade gracefully,
//! never deadlock, and account for everything it drops.

use std::sync::Arc;
use tokenscale::perfmodel::{catalog, EngineModel};
use tokenscale::report::runner::RunOverrides;
use tokenscale::report::{deployment, run_experiment, ExperimentSpec, PolicyKind};
use tokenscale::sim::{simulate, ClusterConfig, SimConfig, StaticCoordinator};
use tokenscale::trace::{step_trace, Trace};
use tokenscale::workload::Request;

fn engine() -> Arc<EngineModel> {
    Arc::new(EngineModel::new(
        catalog::model("llama-3.1-8b").unwrap(),
        catalog::gpu("a100-40g").unwrap(),
        1,
    ))
}

fn cluster_cfg(max_gpus: usize) -> ClusterConfig {
    ClusterConfig {
        prefill_engine: engine(),
        decode_engine: engine(),
        startup_override_s: None,
        max_gpus,
        convertible_chunk_size: 512,
        convertible_reserve_tokens: 4096.0,
    }
}

#[test]
fn empty_trace_completes_instantly() {
    let trace = Trace {
        name: "empty".into(),
        duration_s: 10.0,
        requests: vec![],
    };
    let mut coord = StaticCoordinator::new(1, 1);
    let res = simulate(SimConfig::default(), cluster_cfg(4), &mut coord, &trace);
    assert_eq!(res.metrics.completions.len(), 0);
    assert_eq!(res.metrics.dropped, 0);
}

#[test]
fn oversized_request_is_rejected_not_deadlocked() {
    // A request whose KV footprint exceeds a whole decoder is rejected and
    // accounted; everything else still completes.
    let cap_tokens = engine().kv_capacity_tokens() as usize;
    let mut requests = vec![
        Request::new(0, 0.1, 256, 64),
        Request::new(1, 0.2, 8192, cap_tokens), // impossible
        Request::new(2, 0.3, 256, 64),
    ];
    requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    let trace = Trace {
        name: "oversized".into(),
        duration_s: 5.0,
        requests,
    };
    let mut coord = StaticCoordinator::new(1, 1);
    let res = simulate(SimConfig::default(), cluster_cfg(4), &mut coord, &trace);
    assert_eq!(res.metrics.dropped, 1, "oversized request must be dropped");
    assert_eq!(res.metrics.completions.len(), 2, "others must complete");
}

#[test]
fn simultaneous_arrivals_are_handled() {
    let requests: Vec<Request> = (0..50)
        .map(|i| Request::new(i, 1.0, 128, 16))
        .collect();
    let trace = Trace {
        name: "thundering-herd".into(),
        duration_s: 5.0,
        requests,
    };
    let mut coord = StaticCoordinator::new(2, 2);
    let cfg = SimConfig {
        initial_prefillers: 2,
        initial_decoders: 2,
        ..Default::default()
    };
    let res = simulate(cfg, cluster_cfg(8), &mut coord, &trace);
    assert_eq!(res.metrics.completions.len(), 50);
}

#[test]
fn tiny_gpu_cap_still_serves_with_degraded_slo() {
    // Cap of 2 GPUs: the autoscaler wants more but can't have them.
    let dep = deployment("small-a100").unwrap();
    let trace = step_trace(16.0, 16.0, 0.0, 0.0, 30.0, 1024, 128, 3); // 2x one prefiller's V_P
    let mut dep2 = dep.clone();
    dep2.max_gpus = 2;
    dep2.initial_prefillers = 1;
    dep2.initial_decoders = 1;
    let res = run_experiment(
        &ExperimentSpec::shared(&dep2, PolicyKind::named("tokenscale"), &trace).with_overrides(
            RunOverrides {
                convertibles: Some(0),
                warmup_s: 0.0,
                ..Default::default()
            },
        ),
    );
    // Overload: most requests finish (eventually) and none vanish.
    assert!(res.report.n + res.sim.metrics.dropped > 0);
    assert!(
        res.report.overall_attainment < 0.9,
        "a 2-GPU cluster can't meet SLOs at this load (got {})",
        res.report.overall_attainment
    );
}

#[test]
fn zero_output_predictor_accuracy_still_works() {
    let dep = deployment("small-a100").unwrap();
    let trace = step_trace(6.0, 6.0, 0.0, 0.0, 30.0, 512, 128, 5);
    let res = run_experiment(
        &ExperimentSpec::shared(&dep, PolicyKind::named("tokenscale"), &trace).with_overrides(
            RunOverrides {
                predictor_accuracy: Some(0.0),
                warmup_s: 0.0,
                ..Default::default()
            },
        ),
    );
    // Always-wrong predictions cost efficiency, never correctness.
    assert_eq!(res.report.n, trace.requests.len());
}

#[test]
fn draining_prefiller_finishes_queue() {
    // Scale down mid-burst: requests already queued on the retired
    // prefiller must still complete.
    use tokenscale::sim::{Action, ClusterView, ControlPlane, Role, Signal};

    struct ShrinkAt {
        t: f64,
    }
    impl ControlPlane for ShrinkAt {
        fn name(&self) -> &str {
            "shrink"
        }
        fn on_signal(
            &mut self,
            now: f64,
            signal: Signal<'_>,
            view: &ClusterView<'_>,
            actions: &mut Vec<Action>,
        ) {
            match signal {
                Signal::Arrival(req) | Signal::RetryPrefill(req) => {
                    if let Some(i) = view
                        .running_of(Role::Prefiller)
                        .min_by_key(|i| i.inflight_prefill_tokens())
                    {
                        actions.push(Action::RoutePrefill {
                            req: req.id,
                            target: i.id,
                        });
                    }
                }
                Signal::PrefillDone(req) => {
                    if let Some(i) = view
                        .running_of(Role::Decoder)
                        .filter(|i| i.can_admit(req.total_tokens()))
                        .min_by_key(|i| i.decode_load())
                    {
                        actions.push(Action::DispatchDecode {
                            req: req.id,
                            decoder: i.id,
                            bucket: 0,
                        });
                    }
                }
                Signal::Tick => {
                    actions.push(Action::SetFleet {
                        role: Role::Prefiller,
                        target: if now >= self.t { 1 } else { 3 },
                    });
                    actions.push(Action::SetFleet {
                        role: Role::Decoder,
                        target: 2,
                    });
                }
                _ => {}
            }
        }
    }

    let trace = step_trace(10.0, 10.0, 0.0, 0.0, 20.0, 1024, 32, 7);
    let mut coord = ShrinkAt { t: 5.0 };
    let cfg = SimConfig {
        initial_prefillers: 3,
        initial_decoders: 2,
        ..Default::default()
    };
    let res = simulate(cfg, cluster_cfg(8), &mut coord, &trace);
    assert_eq!(
        res.metrics.completions.len(),
        trace.requests.len(),
        "scale-down dropped requests"
    );
    assert!(res.scale_downs >= 2);
}
