//! Integration: the four control planes over the same bursty trace must
//! reproduce the paper's headline shape (Fig. 9): TokenScale on (or near)
//! the top-left of the attainment-vs-cost frontier.
//!
//! Policies are selected by registry name and run through the shared
//! runner — the same string-keyed path the CLI and every bench use.

use tokenscale::metrics::SloReport;
use tokenscale::report::{deployment, run_experiment, ExperimentSpec, PolicyKind};
use tokenscale::trace::{generate_family, Trace, TraceFamily};

fn run_policy(name: &str, trace: &Trace) -> SloReport {
    let dep = deployment("small-a100").unwrap();
    let res = run_experiment(&ExperimentSpec::shared(&dep, PolicyKind::named(name), trace));
    let report = res.report;
    eprintln!(
        "{name:12} attainment={:.3} (ttft {:.3} tpot {:.3}) gpus={:.2} n={}",
        report.overall_attainment,
        report.ttft_attainment,
        report.tpot_attainment,
        report.avg_gpus,
        report.n
    );
    assert_eq!(
        report.rejected_actions, 0,
        "{name}: stock policies must not have actions rejected"
    );
    report
}

#[test]
fn tokenscale_dominates_on_bursty_mixed_trace() {
    // Paper-like conditions: ~22 RPS mixed trace on the 16-GPU small
    // cluster (§V), so overprovisioning policies hit the cluster cap and
    // bursts overwhelm slow reactions.
    let trace = generate_family(TraceFamily::Mixed, 22.0, 180.0, 42);
    let ts = run_policy("tokenscale", &trace);
    let ai = run_policy("aibrix", &trace);
    let bz = run_policy("blitzscale", &trace);
    let ds = run_policy("distserve", &trace);

    // Every system completes the workload.
    for (n, r) in [("ts", &ts), ("ai", &ai), ("bz", &bz), ("ds", &ds)] {
        assert!(r.n > 500, "{n} completed only {}", r.n);
    }

    // The paper's headline shape: TokenScale's attainment beats every
    // baseline's.
    for (n, r) in [("aibrix", &ai), ("blitzscale", &bz), ("distserve", &ds)] {
        assert!(
            ts.overall_attainment >= r.overall_attainment - 0.02,
            "tokenscale {:.3} should be >= {n} {:.3}",
            ts.overall_attainment,
            r.overall_attainment
        );
    }
    // And reaches a high absolute attainment (paper: 80-96%).
    assert!(
        ts.overall_attainment > 0.75,
        "tokenscale attainment {:.3}",
        ts.overall_attainment
    );
}
