//! Integration: the four control planes over the same bursty trace must
//! reproduce the paper's headline shape (Fig. 9): TokenScale on (or near)
//! the top-left of the attainment-vs-cost frontier.

use std::sync::Arc;
use tokenscale::coordinator::{TokenScale, TokenScaleConfig};
use tokenscale::metrics::SloReport;
use tokenscale::perfmodel::{catalog, EngineModel};
use tokenscale::scaler::{derive_thresholds, AiBrix, BlitzScale, DistServe};
use tokenscale::sim::{simulate, ClusterConfig, Coordinator, SimConfig};
use tokenscale::trace::{generate_family, Trace, TraceFamily};
use tokenscale::velocity::VelocityProfile;
use tokenscale::workload::SloPolicy;

fn engine() -> Arc<EngineModel> {
    Arc::new(EngineModel::new(
        catalog::model("llama-3.1-8b").unwrap(),
        catalog::gpu("a100-40g").unwrap(),
        1,
    ))
}

fn cluster_cfg(convertible_chunk: usize, reserve: f64) -> ClusterConfig {
    ClusterConfig {
        prefill_engine: engine(),
        decode_engine: engine(),
        startup_override_s: None,
        max_gpus: 16,
        convertible_chunk_size: convertible_chunk,
        convertible_reserve_tokens: reserve,
    }
}

fn run_policy(name: &str, trace: &Trace) -> SloReport {
    let eng = engine();
    let link = catalog::link("a100-cluster").unwrap();
    let avg_in = trace.avg_input_tokens();
    let avg_total = avg_in + trace.avg_output_tokens();
    let profile = VelocityProfile::analytic(&eng, &link, avg_in as usize);
    let thresholds = derive_thresholds(trace, &eng, &profile);
    let slo = SloPolicy::default();

    let base_sim = SimConfig {
        initial_prefillers: 2,
        initial_decoders: 2,
        initial_convertibles: 0,
        ..Default::default()
    };

    let (report, label) = match name {
        "tokenscale" => {
            let mut ts = TokenScale::new(
                TokenScaleConfig::default(),
                &eng,
                &link,
                avg_in as usize,
                avg_total,
            );
            let cfg = SimConfig {
                initial_convertibles: ts.cfg.convertibles,
                ..base_sim.clone()
            };
            let ccfg = cluster_cfg(ts.chunk_size, ts.reserve_tokens);
            let res = simulate(cfg, ccfg, &mut ts, trace);
            (res.metrics.report(&slo, 10.0), ts.name().to_string())
        }
        "aibrix" => {
            let mut p = AiBrix::new(&thresholds);
            let res = simulate(base_sim.clone(), cluster_cfg(0, 0.0), &mut p, trace);
            (res.metrics.report(&slo, 10.0), p.name().to_string())
        }
        "blitzscale" => {
            let mut p = BlitzScale::new(&thresholds);
            let res = simulate(base_sim.clone(), cluster_cfg(0, 0.0), &mut p, trace);
            (res.metrics.report(&slo, 10.0), p.name().to_string())
        }
        "distserve" => {
            let mut p = DistServe::new(&thresholds);
            let res = simulate(base_sim.clone(), cluster_cfg(0, 0.0), &mut p, trace);
            (res.metrics.report(&slo, 10.0), p.name().to_string())
        }
        _ => unreachable!(),
    };
    eprintln!(
        "{label:12} attainment={:.3} (ttft {:.3} tpot {:.3}) gpus={:.2} n={}",
        report.overall_attainment,
        report.ttft_attainment,
        report.tpot_attainment,
        report.avg_gpus,
        report.n
    );
    report
}

#[test]
fn tokenscale_dominates_on_bursty_mixed_trace() {
    // Paper-like conditions: ~22 RPS mixed trace on the 16-GPU small
    // cluster (§V), so overprovisioning policies hit the cluster cap and
    // bursts overwhelm slow reactions.
    let trace = generate_family(TraceFamily::Mixed, 22.0, 180.0, 42);
    let ts = run_policy("tokenscale", &trace);
    let ai = run_policy("aibrix", &trace);
    let bz = run_policy("blitzscale", &trace);
    let ds = run_policy("distserve", &trace);

    // Every system completes the workload.
    for (n, r) in [("ts", &ts), ("ai", &ai), ("bz", &bz), ("ds", &ds)] {
        assert!(r.n > 500, "{n} completed only {}", r.n);
    }

    // The paper's headline shape: TokenScale's attainment beats every
    // baseline's.
    for (n, r) in [("aibrix", &ai), ("blitzscale", &bz), ("distserve", &ds)] {
        assert!(
            ts.overall_attainment >= r.overall_attainment - 0.02,
            "tokenscale {:.3} should be >= {n} {:.3}",
            ts.overall_attainment,
            r.overall_attainment
        );
    }
    // And reaches a high absolute attainment (paper: 80-96%).
    assert!(
        ts.overall_attainment > 0.75,
        "tokenscale attainment {:.3}",
        ts.overall_attainment
    );
}
