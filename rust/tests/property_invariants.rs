//! Property-based invariants over the simulator, scalers, router and
//! workload substrate, using the hand-rolled `util::prop` harness
//! (PROP_CASES / PROP_SEED env vars control case count and seeding).

use std::sync::Arc;
use tokenscale::perfmodel::{catalog, EngineModel};
use tokenscale::scaler::tokenscale::Hysteresis;
use tokenscale::scaler::{required_decoders_frac, required_prefillers};
use tokenscale::sim::{simulate, ClusterConfig, SimConfig, StaticCoordinator};
use tokenscale::trace::{generate_family, step_trace, TraceFamily};
use tokenscale::util::prop::{check, Config};
use tokenscale::util::rng::Pcg64;
use tokenscale::velocity::VelocityProfile;
use tokenscale::workload::{all_buckets, BucketScheme, SloPolicy};

fn engine() -> Arc<EngineModel> {
    Arc::new(EngineModel::new(
        catalog::model("llama-3.1-8b").unwrap(),
        catalog::gpu("a100-40g").unwrap(),
        1,
    ))
}

fn cluster_cfg(max_gpus: usize) -> ClusterConfig {
    ClusterConfig {
        prefill_engine: engine(),
        decode_engine: engine(),
        startup_override_s: None,
        max_gpus,
        convertible_chunk_size: 512,
        convertible_reserve_tokens: 4096.0,
        kvcache: tokenscale::sim::KvCacheConfig::disabled(),
    }
}

/// Conservation: every request in a feasible workload is eventually
/// completed exactly once, with sane latencies (no loss, no duplication).
#[test]
fn prop_simulation_conserves_requests() {
    check(Config::named("sim-conservation").cases(12), |rng| {
        let rps = rng.range_f64(1.0, 6.0);
        let input = rng.range_usize(16, 2048);
        let output = rng.range_usize(4, 256);
        let trace = step_trace(rps, rps, 0.0, 0.0, 20.0, input, output, rng.next_u64());
        let n = trace.requests.len();
        let mut coord = StaticCoordinator::new(2, 2);
        let cfg = SimConfig {
            initial_prefillers: 2,
            initial_decoders: 2,
            drain_s: 600.0,
            ..Default::default()
        };
        let res = simulate(cfg, cluster_cfg(8), &mut coord, &trace);
        assert_eq!(
            res.metrics.completions.len() + res.metrics.dropped,
            n,
            "requests lost (rps={rps:.1} in={input} out={output})"
        );
        let mut ids: Vec<u64> = res.metrics.completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), res.metrics.completions.len(), "duplicate completions");
        for c in &res.metrics.completions {
            assert!(c.ttft > 0.0 && c.ttft.is_finite());
            assert!(c.finish >= c.arrival + c.ttft - 1e-9);
        }
    });
}

/// The GPU-cost integral is bounded by cap × horizon and is non-negative.
#[test]
fn prop_gpu_cost_bounded_by_cap() {
    check(Config::named("gpu-cost-bound").cases(10), |rng| {
        let cap = rng.range_usize(2, 12);
        let trace = generate_family(
            TraceFamily::AzureConv,
            rng.range_f64(2.0, 15.0),
            60.0,
            rng.next_u64(),
        );
        let mut coord = StaticCoordinator::new(1, 1);
        let cfg = SimConfig::default();
        let res = simulate(cfg, cluster_cfg(cap), &mut coord, &trace);
        let max_cost = cap as f64 * res.horizon_s;
        assert!(res.metrics.gpu_seconds >= 0.0);
        assert!(
            res.metrics.gpu_seconds <= max_cost + 1e-6,
            "cost {} exceeds cap bound {}",
            res.metrics.gpu_seconds,
            max_cost
        );
    });
}

/// Eq. 2 monotonicity: more arriving tokens can never require fewer
/// prefillers; Eq. 3 likewise per bucket.
#[test]
fn prop_scaler_monotone_in_load() {
    let engine = engine();
    let link = catalog::link("a100-cluster").unwrap();
    let profile = VelocityProfile::analytic(&engine, &link, 1024);
    check(Config::named("scaler-monotone").cases(200), |rng| {
        let a = rng.range_f64(0.0, 80_000.0);
        let b = rng.range_f64(0.0, 80_000.0);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        assert!(required_prefillers(lo, &profile) <= required_prefillers(hi, &profile));

        let mut lam_lo = [0.0; 9];
        let mut lam_hi = [0.0; 9];
        for i in 0..9 {
            let x = rng.range_f64(0.0, 30_000.0);
            let y = rng.range_f64(0.0, 10_000.0);
            lam_lo[i] = x;
            lam_hi[i] = x + y;
        }
        assert!(
            required_decoders_frac(&lam_lo, &profile)
                <= required_decoders_frac(&lam_hi, &profile) + 1e-9
        );
    });
}

/// Hysteresis safety: output target is always between min(current, target)
/// and max(current, target) — it never overshoots in either direction.
#[test]
fn prop_hysteresis_bounded() {
    check(Config::named("hysteresis-bounded").cases(200), |rng| {
        let mut h = Hysteresis::new(rng.range_usize(1, 30));
        let mut current = rng.range_usize(0, 20);
        for _ in 0..100 {
            let target = rng.range_usize(0, 20);
            let out = h.apply(current, target);
            let lo = current.min(target);
            let hi = current.max(target);
            assert!(
                (lo..=hi).contains(&out),
                "hysteresis escaped [{lo},{hi}]: {out}"
            );
            current = out;
        }
    });
}

/// Bucket classification is total and consistent with its representatives.
#[test]
fn prop_bucket_classification_total() {
    let scheme = BucketScheme::default();
    check(Config::named("bucket-total").cases(500), |rng| {
        let input = rng.range_usize(1, 10_000);
        let output = rng.range_usize(1, 2_000);
        let b = scheme.classify(input, output);
        assert!(b.index() < 9);
        // Representatives classify back into their own bucket.
        for bb in all_buckets() {
            let (i, o) = scheme.representative(bb);
            assert_eq!(scheme.classify(i, o), bb);
        }
    });
}

/// SLO checks: ttft_slo is monotone non-increasing in strictness (longer
/// prompts never get tighter deadlines).
#[test]
fn prop_slo_monotone() {
    let slo = SloPolicy::default();
    check(Config::named("slo-monotone").cases(300), |rng| {
        let a = rng.range_usize(1, 8192);
        let b = rng.range_usize(1, 8192);
        let (short, long) = if a <= b { (a, b) } else { (b, a) };
        assert!(slo.ttft_slo(short) <= slo.ttft_slo(long));
    });
}

/// Telemetry under churn: arming observation never perturbs the
/// trajectory (completions bit-identical to an observe-off run), and
/// every sampled request's span chain stays well-formed — time-ordered,
/// arrival-first, exactly one terminal — even when crashes and transfer
/// brownouts displace in-flight work and force requeues mid-chain.
#[test]
fn prop_span_chains_hold_under_faults() {
    use tokenscale::obs::{ObserveConfig, SpanKind};
    use tokenscale::sim::{FaultKind, FaultPlan, FaultSchedule, FaultSpec, Role};
    check(Config::named("span-chains-faults").cases(8), |rng| {
        let rps = rng.range_f64(2.0, 6.0);
        let output = rng.range_usize(32, 256);
        let trace = step_trace(rps, rps, 0.0, 0.0, 20.0, 512, output, rng.next_u64());
        let faults = FaultPlan {
            seed: rng.next_u64(),
            entries: vec![
                FaultSpec {
                    kind: FaultKind::Crash,
                    role: Some(if rng.range_usize(0, 1) == 0 {
                        Role::Decoder
                    } else {
                        Role::Prefiller
                    }),
                    instance_index: None,
                    schedule: FaultSchedule::At {
                        t: rng.range_f64(4.0, 10.0),
                    },
                },
                FaultSpec {
                    kind: FaultKind::Transfer {
                        loss_prob: rng.range_f64(0.3, 1.0),
                        stall_s: 1.0,
                        max_retries: 2,
                        duration_s: rng.range_f64(3.0, 8.0),
                    },
                    role: None,
                    instance_index: None,
                    schedule: FaultSchedule::At {
                        t: rng.range_f64(6.0, 12.0),
                    },
                },
            ],
        };
        let base = SimConfig {
            initial_prefillers: 2,
            initial_decoders: 2,
            faults,
            ..Default::default()
        };
        let mut coord_off = StaticCoordinator::new(2, 2);
        let off = simulate(base.clone(), cluster_cfg(8), &mut coord_off, &trace);
        let on_cfg = SimConfig {
            observe: Some(ObserveConfig {
                sample_s: 2.0,
                span_sample_n: 1,
                seed: 0,
                sinks: vec![],
            }),
            ..base
        };
        let mut coord_on = StaticCoordinator::new(2, 2);
        let on = simulate(on_cfg, cluster_cfg(8), &mut coord_on, &trace);

        // Passivity: identical trajectory bit for bit.
        assert_eq!(off.events_processed, on.events_processed);
        assert_eq!(off.metrics.completions.len(), on.metrics.completions.len());
        for (a, b) in off.metrics.completions.iter().zip(&on.metrics.completions) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.finish.to_bits(), b.finish.to_bits());
        }

        // Chain invariant with every request sampled (n=1).
        let obs = on.obs.expect("observe armed");
        obs.spans
            .check_chains(true)
            .unwrap_or_else(|e| panic!("chain violated under faults: {e}"));
        let chains = obs.spans.by_request();
        assert_eq!(
            chains.len(),
            trace.requests.len(),
            "every request gets a chain at n=1"
        );
        let terminals = obs
            .spans
            .events
            .iter()
            .filter(|e| e.kind.is_terminal())
            .count();
        assert_eq!(terminals, chains.len(), "every chain resolves exactly once");
        let completions = obs
            .spans
            .events
            .iter()
            .filter(|e| e.kind == SpanKind::Completion)
            .count();
        assert_eq!(
            completions,
            on.metrics.completions.len(),
            "span terminals agree with the metrics ledger"
        );
    });
}

/// Trace generators: arrivals sorted, lengths within bounds, rate within a
/// factor of the request across all families and seeds.
#[test]
fn prop_trace_generator_sane() {
    check(Config::named("trace-sane").cases(16), |rng: &mut Pcg64| {
        let fams = [
            TraceFamily::AzureConv,
            TraceFamily::AzureCode,
            TraceFamily::BurstGpt1,
            TraceFamily::BurstGpt2,
            TraceFamily::Mixed,
        ];
        let fam = fams[rng.range_usize(0, fams.len() - 1)];
        let rps = rng.range_f64(2.0, 40.0);
        let trace = tokenscale::trace::generate_family(fam, rps, 120.0, rng.next_u64());
        assert!(!trace.requests.is_empty(), "{fam:?} empty at {rps}");
        for w in trace.requests.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        for r in &trace.requests {
            assert!(r.input_tokens >= 1 && r.input_tokens <= 8192);
            assert!(r.output_tokens >= 1 && r.output_tokens <= 1024);
            assert!(r.arrival >= 0.0 && r.arrival < 120.0);
        }
        let measured = trace.avg_rps();
        assert!(
            measured > rps * 0.4 && measured < rps * 2.0,
            "{fam:?}: rps {measured:.1} vs requested {rps:.1}"
        );
    });
}
