//! End-to-end integration over the REAL engine (gated on `make artifacts`):
//! the three-layer stack must serve a mixed batch with correct bookkeeping
//! and deterministic greedy outputs, and the convertible-decoder compute
//! path must agree with the one-shot prefill path.

use tokenscale::runtime::{artifacts_available, artifacts_dir, RealEngine};
use tokenscale::server::{PdServer, ServeRequest};

fn gated() -> bool {
    if !artifacts_available() {
        eprintln!("artifacts/ missing — run `make artifacts` (test skipped)");
        return false;
    }
    true
}

#[test]
fn pd_server_serves_mixed_batch() {
    if !gated() {
        return;
    }
    let requests: Vec<ServeRequest> = (0..10u64)
        .map(|i| ServeRequest {
            id: i,
            prompt: (0..(3 + (i as i32 * 7) % 50))
                .map(|t| (t * 29 + i as i32) % 500)
                .collect(),
            max_new_tokens: 4 + (i as usize % 5),
        })
        .collect();
    let expect: Vec<(u64, usize)> = requests.iter().map(|r| (r.id, r.max_new_tokens)).collect();
    let report = PdServer::serve_all(requests).unwrap();
    assert_eq!(report.completions.len(), 10);
    for (id, want) in expect {
        let c = report.completions.iter().find(|c| c.id == id).unwrap();
        assert_eq!(c.tokens.len(), want, "req {id} token count");
        assert!(c.tokens.iter().all(|t| (0..512).contains(t)));
        assert!(c.ttft > 0.0);
    }
}

#[test]
fn pd_server_is_deterministic_across_runs() {
    if !gated() {
        return;
    }
    let mk = || -> Vec<ServeRequest> {
        (0..4u64)
            .map(|i| ServeRequest {
                id: i,
                prompt: (0..10).map(|t| (t * 31 + i as i32 * 3) % 500).collect(),
                max_new_tokens: 6,
            })
            .collect()
    };
    let a = PdServer::serve_all(mk()).unwrap();
    let b = PdServer::serve_all(mk()).unwrap();
    for id in 0..4u64 {
        let ta = &a.completions.iter().find(|c| c.id == id).unwrap().tokens;
        let tb = &b.completions.iter().find(|c| c.id == id).unwrap().tokens;
        assert_eq!(ta, tb, "greedy decoding must be run-invariant (req {id})");
    }
}

#[test]
fn convertible_chunked_path_matches_prefill_across_prompts() {
    if !gated() {
        return;
    }
    let mut engine = RealEngine::load(&artifacts_dir()).unwrap();
    let chunk = engine.meta.chunk;
    for seed in 0..3i32 {
        let len = chunk + 1 + (seed as usize * 9) % (2 * chunk);
        let prompt: Vec<i32> = (0..len as i32).map(|t| (t * 11 + seed * 101) % 500).collect();
        let whole = engine.prefill(&prompt).unwrap();

        let (mut ck, mut cv) = engine.empty_conv_cache();
        let mut off = 0;
        let mut last_logits = Vec::new();
        while off < prompt.len() {
            let end = (off + chunk).min(prompt.len());
            last_logits = engine
                .chunked_prefill(&prompt[off..end], &mut ck, &mut cv, off)
                .unwrap();
            off = end;
        }
        let argmax = |xs: &[f32]| -> i32 {
            let mut b = 0;
            for (i, x) in xs.iter().enumerate() {
                if *x > xs[b] {
                    b = i;
                }
            }
            b as i32
        };
        assert_eq!(
            argmax(&last_logits),
            whole.first_token,
            "chunked vs whole prefill disagree (seed {seed}, len {len})"
        );
    }
}
