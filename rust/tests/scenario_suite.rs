//! Scenario-suite API safety nets:
//!
//! 1. **Serialization** — scenarios and suites round-trip through JSON
//!    and parse from the TOML scenario-file format; malformed documents
//!    surface as *typed* [`ScenarioError`]s (unknown policy names, bad
//!    transform chains), not panics or stringly failures.
//! 2. **Golden schema** — the normalized `BENCH_<suite>.json` layout is
//!    pinned by `rust/tests/golden/bench_schema.golden`; any structural
//!    change must bump [`BENCH_SCHEMA_VERSION`] and update the golden.
//! 3. **Regression gate** — `tokenscale bench diff` exits nonzero on an
//!    injected SLO regression in a fixture and zero on a clean pair.
//! 4. **Library files** — the shipped `scenarios/*.toml` suites (CI's
//!    `smoke`) parse and validate.

use std::collections::BTreeSet;
use tokenscale::report::{
    Scenario, ScenarioError, Suite, TransformStep, WorkloadSpec, BENCH_SCHEMA_VERSION,
};
use tokenscale::trace::{BurstWindow, TraceFamily};
use tokenscale::util::json::Json;
use tokenscale::util::toml;

fn demo_suite() -> Suite {
    Suite::new("demo", "round-trip fixture")
        .scenario(
            Scenario::new(
                "windowed-conv",
                "small-a100",
                WorkloadSpec::Synthetic {
                    family: TraceFamily::AzureConv,
                    rps: 10.0,
                    duration_s: 120.0,
                    seed: 7,
                },
            )
            .policies(&["tokenscale", "distserve"])
            .transform(TransformStep::Window { t0: 0.0, t1: 60.0 })
            .transform(TransformStep::Burst {
                windows: vec![BurstWindow::new(20.0, 10.0, 3.0)],
                seed: 13,
            }),
        )
        .scenario(
            Scenario::new(
                "replayed",
                "small-a100",
                WorkloadSpec::Replay {
                    path: "examples/traces/azure_conv_sample.csv".into(),
                },
            )
            .policy("static"),
        )
}

// ------------------------------------------------------- serialization

#[test]
fn suite_round_trips_through_json_text() {
    let suite = demo_suite();
    let text = suite.to_json().pretty();
    let back = Suite::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(suite, back);
}

#[test]
fn suite_parses_from_toml_format() {
    let text = r#"
name = "demo"
description = "round-trip fixture"

[[scenarios]]
name = "windowed-conv"
deployment = "small-a100"
policies = ["tokenscale", "distserve"]

[scenarios.workload]
kind = "synthetic"
family = "azure-conv"
rps = 10.0
duration_s = 120.0
seed = 7

[[scenarios.transforms]]
op = "window"
t0 = 0.0
t1 = 60.0

[[scenarios.transforms]]
op = "burst"
windows = [{ start_s = 20.0, len_s = 10.0, rate_factor = 3.0 }]
seed = 13

[[scenarios]]
name = "replayed"
deployment = "small-a100"
policies = ["static"]

[scenarios.workload]
kind = "replay"
path = "examples/traces/azure_conv_sample.csv"
"#;
    let doc = toml::parse(text).unwrap();
    let suite = Suite::from_json(&doc).unwrap();
    // The TOML form and the code-built form are the same value, so the
    // two serialization paths cannot drift apart.
    assert_eq!(suite, demo_suite());
}

#[test]
fn unknown_policy_name_is_a_typed_error() {
    let mut doc = demo_suite().to_json();
    // Corrupt the first scenario's policy list.
    let Json::Obj(m) = &mut doc else { panic!() };
    let Json::Arr(scenarios) = m.get_mut("scenarios").unwrap() else { panic!() };
    let Json::Obj(sc) = &mut scenarios[0] else { panic!() };
    sc.insert(
        "policies".into(),
        Json::Arr(vec![Json::Str("gradient-descent".into())]),
    );
    assert_eq!(
        Suite::from_json(&doc),
        Err(ScenarioError::UnknownPolicy { name: "gradient-descent".into() })
    );
}

#[test]
fn bad_transform_chain_is_a_typed_error() {
    let toml_text = r#"
name = "broken"
deployment = "small-a100"
policies = ["tokenscale"]

[workload]
kind = "synthetic"
family = "mixed"
rps = 5.0
duration_s = 30.0

[[transforms]]
op = "window"
t0 = 60.0
t1 = 10.0
"#;
    let doc = toml::parse(toml_text).unwrap();
    let err = Suite::from_json(&doc).unwrap_err();
    assert!(
        matches!(err, ScenarioError::BadTransform { ref op, .. } if op == "window"),
        "{err}"
    );

    let doc = Json::parse(
        r#"{"name":"broken","deployment":"small-a100","policies":["tokenscale"],
            "workload":{"kind":"synthetic","family":"mixed","rps":5,"duration_s":30},
            "transforms":[{"op":"wormhole"}]}"#,
    )
    .unwrap();
    assert_eq!(
        Suite::from_json(&doc),
        Err(ScenarioError::UnknownTransform { op: "wormhole".into() })
    );
}

#[test]
fn unknown_and_malformed_fields_are_typed_errors() {
    // A typo'd key ("transform" instead of "transforms") must not
    // silently run the untransformed workload.
    let doc = Json::parse(
        r#"{"name":"x","deployment":"small-a100","policies":["tokenscale"],
            "workload":{"kind":"synthetic","family":"mixed","rps":5,"duration_s":30},
            "transform":[{"op":"window","t0":0,"t1":10}]}"#,
    )
    .unwrap();
    assert!(matches!(
        Suite::from_json(&doc),
        Err(ScenarioError::UnknownField { ref field, .. }) if field == "transform"
    ));

    // Negative / fractional integer overrides are rejected, not cast.
    for bad in [r#"{"max_gpus":-1}"#, r#"{"decoders":2.7}"#] {
        let doc = Json::parse(&format!(
            r#"{{"name":"x","deployment":"small-a100","policies":["tokenscale"],
                "workload":{{"kind":"synthetic","family":"mixed","rps":5,"duration_s":30}},
                "overrides":{bad}}}"#,
        ))
        .unwrap();
        assert!(
            matches!(Suite::from_json(&doc), Err(ScenarioError::BadValue { .. })),
            "{bad}"
        );
    }
}

// ------------------------------------------------------- golden schema

/// Flatten a normalized report into sorted `path: type` lines, with
/// scenario/policy names generalized so the schema is data-independent
/// (both under `scenarios` and under the `warm_start` block).
fn schema_lines(doc: &Json) -> BTreeSet<String> {
    fn type_name(j: &Json) -> &'static str {
        match j {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }
    fn walk(j: &Json, path: &str, out: &mut BTreeSet<String>) {
        out.insert(format!("{path}: {}", type_name(j)));
        if let Json::Obj(m) = j {
            for (k, v) in m {
                let key = if path == "scenarios" || path == "warm_start" {
                    "<scenario>".to_string()
                } else if path == "scenarios.<scenario>" {
                    "<policy>".to_string()
                } else {
                    k.clone()
                };
                walk(v, &format!("{path}.{key}"), out);
            }
        }
    }
    let mut out = BTreeSet::new();
    if let Json::Obj(m) = doc {
        for (k, v) in m {
            walk(v, k, &mut out);
        }
    }
    out
}

#[test]
fn bench_json_schema_matches_golden() {
    // A tiny two-cell suite materializes every per-cell schema path...
    let run = Suite::new("golden", "schema fixture")
        .scenario(
            Scenario::new(
                "tiny",
                "small-a100",
                WorkloadSpec::Synthetic {
                    family: TraceFamily::AzureConv,
                    rps: 6.0,
                    duration_s: 30.0,
                    seed: 3,
                },
            )
            .policies(&["static", "distserve"])
            .materialized(),
        )
        .run()
        .expect("golden suite runs");
    let doc = run.to_json();
    assert_eq!(
        doc.get("schema_version").and_then(Json::as_f64),
        Some(BENCH_SCHEMA_VERSION as f64)
    );
    // ...and a one-cell warm-started suite materializes the `warm_start`
    // amortization block; the golden pins the union.
    let warm_run = Suite::new("golden-warm", "warm-start schema fixture")
        .scenario(
            Scenario::new(
                "warmed",
                "small-a100",
                WorkloadSpec::Synthetic {
                    family: TraceFamily::AzureConv,
                    rps: 6.0,
                    duration_s: 30.0,
                    seed: 3,
                },
            )
            .policy("static")
            .with_checkpoint(tokenscale::report::CheckpointSpec {
                warm_start_s: 10.0,
                policy: "static".into(),
                every_s: 0.0,
            }),
        )
        .run()
        .expect("warm golden suite runs");

    let mut got = schema_lines(&doc);
    got.extend(schema_lines(&warm_run.to_json()));
    let golden_path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/rust/tests/golden/bench_schema.golden"
    );
    let golden_text = std::fs::read_to_string(golden_path).expect("golden file");
    let want: BTreeSet<String> = golden_text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect();
    assert_eq!(
        got, want,
        "normalized BENCH schema drifted — bump BENCH_SCHEMA_VERSION and regenerate the golden\n\
         missing from golden: {:?}\nextra in golden: {:?}",
        got.difference(&want).collect::<Vec<_>>(),
        want.difference(&got).collect::<Vec<_>>()
    );
}

// ------------------------------------------------------ regression gate

fn bench_doc(slo: f64, gpu: f64) -> String {
    Json::obj()
        .set("schema_version", BENCH_SCHEMA_VERSION)
        .set("suite", "fixture")
        .set("wall_s", 1.0)
        .set(
            "scenarios",
            Json::obj().set(
                "s1",
                Json::obj().set(
                    "tokenscale",
                    Json::obj().set("slo_attainment", slo).set("gpu_hours", gpu),
                ),
            ),
        )
        .pretty()
}

#[test]
fn bench_diff_cli_exits_nonzero_on_injected_slo_regression() {
    let dir = std::env::temp_dir();
    let cur = dir.join("tokenscale_test_current.json");
    let base = dir.join("tokenscale_test_baseline.json");
    // Injected regression: attainment collapses 0.95 -> 0.80.
    std::fs::write(&cur, bench_doc(0.80, 1.0)).unwrap();
    std::fs::write(&base, bench_doc(0.95, 1.0)).unwrap();

    let argv = |c: &std::path::Path, b: &std::path::Path| {
        vec![
            "bench".to_string(),
            "diff".to_string(),
            c.display().to_string(),
            b.display().to_string(),
        ]
    };
    let code = tokenscale::cli::run_cli(argv(&cur, &base));
    assert_ne!(code, 0, "regression must fail the diff");

    // The reverse direction is an improvement: clean exit.
    let code = tokenscale::cli::run_cli(argv(&base, &cur));
    assert_eq!(code, 0, "improvement must pass the diff");

    // Identical reports: clean exit.
    let code = tokenscale::cli::run_cli(argv(&base, &base));
    assert_eq!(code, 0);
}

// ------------------------------------------------------- shipped files

#[test]
fn shipped_smoke_suite_parses_and_validates() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/smoke.toml");
    let suite = Suite::from_path(std::path::Path::new(path)).expect("smoke suite loads");
    assert_eq!(suite.name, "smoke");
    suite.validate().expect("smoke suite validates");
    for want in [
        "compare-mixed",
        "diurnal-conv",
        "flash-crowd",
        "chaos-smoke",
        "splice-replay",
        "planner-smoke",
    ] {
        assert!(
            suite.scenarios.iter().any(|s| s.name == want),
            "smoke suite lacks {want}"
        );
    }
    // The planner cell arms the forecast block for both planner policies.
    let planner = suite
        .scenarios
        .iter()
        .find(|s| s.name == "planner-smoke")
        .unwrap();
    let params = planner.planner.expect("planner-smoke must carry a planner block");
    assert_eq!(params.period_s, 60.0);
    assert!(planner.policies.iter().any(|p| p == "sla-planner"));
    assert!(planner.policies.iter().any(|p| p == "sla-hybrid"));
    // The chaos cell carries an armed, seeded fault plan.
    let chaos = suite
        .scenarios
        .iter()
        .find(|s| s.name == "chaos-smoke")
        .unwrap();
    assert!(!chaos.faults.is_empty(), "chaos-smoke must arm faults");
    assert_eq!(chaos.faults.seed, 616);
    // The replay scenario's transform chain has the Window splice.
    let splice = suite
        .scenarios
        .iter()
        .find(|s| s.name == "splice-replay")
        .unwrap();
    assert!(matches!(splice.workload, WorkloadSpec::Replay { .. }));
    assert!(splice
        .transforms
        .iter()
        .any(|t| matches!(t, TransformStep::Window { .. })));
    // The telemetry cell arms observe with all four sinks (CI uploads
    // its artifacts); every other cell leaves observe off.
    let obs_cell = suite
        .scenarios
        .iter()
        .find(|s| s.name == "obs-smoke")
        .expect("smoke suite lacks obs-smoke");
    let o = obs_cell
        .observe
        .as_ref()
        .expect("obs-smoke must carry an observe block");
    assert_eq!(o.sample_s, 5.0);
    assert_eq!(o.span_sample_n, 4);
    assert_eq!(o.seed, 17);
    assert_eq!(o.sinks, tokenscale::obs::Sink::ALL.to_vec());
    assert!(
        suite
            .scenarios
            .iter()
            .all(|s| s.name == "obs-smoke" || s.observe.is_none()),
        "only obs-smoke arms telemetry in the smoke suite"
    );
}

#[test]
fn shipped_chaos_suite_parses_and_validates() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/chaos.toml");
    let suite = Suite::from_path(std::path::Path::new(path)).expect("chaos suite loads");
    assert_eq!(suite.name, "chaos");
    suite.validate().expect("chaos suite validates");
    for want in [
        "crash-flash-crowd",
        "rolling-preempt",
        "straggler-prefill",
        "transfer-brownout",
    ] {
        let sc = suite
            .scenarios
            .iter()
            .find(|s| s.name == want)
            .unwrap_or_else(|| panic!("chaos suite lacks {want}"));
        assert!(!sc.faults.is_empty(), "{want} must arm a fault plan");
        // Goodput-under-churn compares the full baseline panel.
        assert_eq!(sc.policies.len(), 4, "{want} must run all four baselines");
    }
}

#[test]
fn shipped_planner_suite_parses_and_validates() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/planner.toml");
    let suite = Suite::from_path(std::path::Path::new(path)).expect("planner suite loads");
    assert_eq!(suite.name, "planner");
    suite.validate().expect("planner suite validates");
    for want in ["planner-diurnal", "planner-flash"] {
        let sc = suite
            .scenarios
            .iter()
            .find(|s| s.name == want)
            .unwrap_or_else(|| panic!("planner suite lacks {want}"));
        // Every cell compares the planner family against reactive baselines.
        let params = sc.planner.unwrap_or_else(|| panic!("{want} must carry a planner block"));
        assert!(params.period_s >= params.sample_s);
        for policy in ["tokenscale", "sla-planner", "sla-hybrid", "distserve"] {
            assert!(
                sc.policies.iter().any(|p| p == policy),
                "{want} must run {policy}"
            );
        }
    }
    // The diurnal cell warm-starts from a shared checkpoint prefix.
    let diurnal = suite
        .scenarios
        .iter()
        .find(|s| s.name == "planner-diurnal")
        .unwrap();
    assert!(diurnal.checkpoint.is_some(), "planner-diurnal must warm-start");
}

#[test]
fn shipped_slo_sweep_suite_parses_and_sweeps_targets() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/slo-sweep.toml");
    let suite = Suite::from_path(std::path::Path::new(path)).expect("slo-sweep suite loads");
    assert_eq!(suite.name, "slo-sweep");
    suite.validate().expect("slo-sweep suite validates");
    assert_eq!(suite.scenarios.len(), 3);
    // The sweep moves only the SLO block: targets strictly relax...
    let targets: Vec<f64> = suite
        .scenarios
        .iter()
        .map(|s| s.slo.expect("slo block present").ttft_medium_s)
        .collect();
    assert!(
        targets.windows(2).all(|w| w[0] < w[1]),
        "targets must relax monotonically: {targets:?}"
    );
    // ...while the workload (and its transform chain) stays identical.
    for sc in &suite.scenarios {
        assert_eq!(sc.workload, suite.scenarios[0].workload);
        assert_eq!(sc.transforms, suite.scenarios[0].transforms);
    }
}

// ---------------------------------------------------------- telemetry

fn tiny_scenario(name: &str) -> Scenario {
    Scenario::new(
        name,
        "small-a100",
        WorkloadSpec::Synthetic {
            family: TraceFamily::AzureConv,
            rps: 6.0,
            duration_s: 30.0,
            seed: 3,
        },
    )
    .policy("static")
}

/// An observe-armed suite cell writes one artifact per configured sink,
/// and each artifact is well-formed: the timeline is columnar JSON, the
/// Perfetto file is Chrome trace-event JSON, the CSV carries the span
/// header and the Prometheus exposition renders typed metric families.
#[test]
fn observe_armed_suite_writes_parsing_artifacts() {
    use tokenscale::obs::{ObserveConfig, Sink};
    let run = Suite::new("obs-artifacts", "telemetry artifact fixture")
        .scenario(tiny_scenario("tiny-obs").with_observe(ObserveConfig {
            sample_s: 5.0,
            span_sample_n: 1,
            seed: 0,
            sinks: Sink::ALL.to_vec(),
        }))
        .run()
        .expect("observed suite runs");
    let dir = std::env::temp_dir().join("tokenscale_test_obs_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    let written = run.write_observe_artifacts(&dir).expect("artifacts write");
    let names: Vec<String> = written
        .iter()
        .map(|p| p.file_name().unwrap().to_string_lossy().into_owned())
        .collect();
    assert_eq!(
        names,
        vec![
            "TIMELINE_tiny-obs__static.json",
            "SPANS_tiny-obs__static.perfetto.json",
            "SPANS_tiny-obs__static.csv",
            "PROM_tiny-obs__static.prom",
        ]
    );

    let read = |i: usize| std::fs::read_to_string(&written[i]).unwrap();
    // Columnar timeline: schema 1, one array of `rows` values per column.
    let timeline = Json::parse(&read(0)).expect("timeline parses");
    assert_eq!(timeline.get("schema").and_then(Json::as_f64), Some(1.0));
    let rows = timeline.get("rows").and_then(Json::as_f64).unwrap() as usize;
    assert!(rows > 0, "30s at 5s sampling must produce rows");
    let Some(Json::Obj(cols)) = timeline.get("columns") else {
        panic!("timeline lacks a columns object")
    };
    assert_eq!(cols.len(), tokenscale::obs::timeline::COLUMNS.len());
    for (name, col) in cols {
        assert_eq!(col.as_arr().map(|a| a.len()), Some(rows), "column {name}");
    }
    // Chrome trace-event JSON: a traceEvents array of phased events.
    let perfetto = Json::parse(&read(1)).expect("perfetto parses");
    let events = perfetto
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert!(!events.is_empty());
    for ev in events {
        assert!(ev.get("ph").is_some() && ev.get("pid").is_some(), "{ev:?}");
    }
    // Flat span CSV.
    assert!(read(2).starts_with("req,t_s,event,role,slot,aux\n"));
    // Prometheus exposition: typed families from both the final timeline
    // sample and the cell's SLO report.
    let prom = read(3);
    assert!(prom.contains("# TYPE"));
    assert!(prom.contains("tokenscale_fleet_size"));
    assert!(prom.contains("scenario=\"tiny-obs\""));
}

/// Suite-level passivity: arming telemetry leaves every normalized
/// outcome identical to the unobserved run (wall-clock aside — the only
/// nondeterministic field in the report), and a suite with no observe
/// blocks writes zero artifacts, leaving the output directory untouched.
#[test]
fn telemetry_is_passive_at_the_suite_level() {
    use tokenscale::obs::{ObserveConfig, Sink};
    let off = Suite::new("passivity", "passivity fixture")
        .scenario(tiny_scenario("tiny"))
        .run()
        .expect("unobserved suite runs");
    let on = Suite::new("passivity", "passivity fixture")
        .scenario(tiny_scenario("tiny").with_observe(ObserveConfig {
            sample_s: 2.0,
            span_sample_n: 1,
            seed: 9,
            sinks: Sink::ALL.to_vec(),
        }))
        .run()
        .expect("observed suite runs");

    // Byte-identical normalized reports once real wall-clock — the only
    // nondeterministic field — is zeroed.
    fn zero_wall(doc: &mut Json) {
        match doc {
            Json::Obj(m) => {
                for (k, v) in m.iter_mut() {
                    if k == "wall_s" {
                        *v = Json::Num(0.0);
                    } else {
                        zero_wall(v);
                    }
                }
            }
            Json::Arr(a) => a.iter_mut().for_each(zero_wall),
            _ => {}
        }
    }
    let normalized = |run: &tokenscale::report::SuiteRun| {
        let mut doc = run.to_json();
        zero_wall(&mut doc);
        doc.pretty()
    };
    assert_eq!(
        normalized(&off),
        normalized(&on),
        "telemetry perturbed the trajectory"
    );

    // The unobserved run holds no telemetry state and writes nothing.
    assert!(off.results[0].sim.obs.is_none());
    let dir = std::env::temp_dir().join("tokenscale_test_obs_passivity");
    std::fs::create_dir_all(&dir).unwrap();
    let before: usize = std::fs::read_dir(&dir).unwrap().count();
    let written = off.write_observe_artifacts(&dir).expect("no-op write");
    assert!(written.is_empty(), "observe-off suite wrote {written:?}");
    assert_eq!(std::fs::read_dir(&dir).unwrap().count(), before);
}
