//! Simulator refactor safety nets:
//!
//! 1. **Determinism** — the same (deployment, policy, trace, seed) must
//!    produce bit-identical completions and reports across two runs in
//!    the same process (no HashMap-iteration or allocation-order leakage
//!    into results).
//! 2. **Coalescing equivalence** — decode-iteration coalescing (the event-
//!    throughput fast path) must be completion-for-completion identical to
//!    the single-step reference mode (`force_single_step`), including on
//!    convertible-decoder workloads where chunked prefill interleaves with
//!    pure-decode windows.

use tokenscale::report::runner::RunOverrides;
use tokenscale::report::{deployment, run_experiment, ExperimentResult, ExperimentSpec, PolicyKind};
use tokenscale::trace::{generate_family, Trace, TraceFamily};

/// Canonical per-request view of a run's completions, sorted by id.
fn completion_key(res: &ExperimentResult) -> Vec<(u64, f64, f64, f64, f64)> {
    let mut v: Vec<(u64, f64, f64, f64, f64)> = res
        .sim
        .metrics
        .completions
        .iter()
        .map(|c| (c.id, c.arrival, c.ttft, c.tpot, c.finish))
        .collect();
    v.sort_by(|a, b| a.0.cmp(&b.0));
    v
}

fn run(policy: PolicyKind, trace: &Trace, ov: &RunOverrides) -> ExperimentResult {
    let dep = deployment("small-a100").unwrap();
    run_experiment(&ExperimentSpec::shared(&dep, policy, trace).with_overrides(ov.clone()))
}

#[test]
fn same_seed_is_bit_deterministic() {
    let trace = generate_family(TraceFamily::AzureConv, 12.0, 90.0, 7);
    let ov = RunOverrides::default();
    let a = run(PolicyKind::named("tokenscale"), &trace, &ov);
    let b = run(PolicyKind::named("tokenscale"), &trace, &ov);
    assert_eq!(completion_key(&a), completion_key(&b));
    assert_eq!(a.sim.metrics.gpu_seconds, b.sim.metrics.gpu_seconds);
    assert_eq!(a.sim.events_processed, b.sim.events_processed);
    assert_eq!(a.report.n, b.report.n);
    assert_eq!(a.report.overall_attainment, b.report.overall_attainment);
    assert_eq!(a.report.ttft.p99, b.report.ttft.p99);
    assert_eq!(a.report.prefill_wait.p99, b.report.prefill_wait.p99);
    assert_eq!(a.sim.scale_ups, b.sim.scale_ups);
    assert_eq!(a.sim.scale_downs, b.sim.scale_downs);
    // Sampled series are part of the contract too.
    assert_eq!(
        a.sim.series.decode_throughput.points,
        b.sim.series.decode_throughput.points
    );
}

fn assert_modes_equivalent(policy: PolicyKind, trace: &Trace, base: RunOverrides) {
    let coalesced = run(policy, trace, &base);
    let single = run(
        policy,
        trace,
        &RunOverrides {
            force_single_step: true,
            ..base
        },
    );
    assert!(
        !coalesced.sim.metrics.completions.is_empty(),
        "workload must complete requests"
    );
    assert_eq!(
        completion_key(&coalesced),
        completion_key(&single),
        "coalesced stepping must reproduce single-step TTFT/TPOT/finish exactly ({})",
        policy.name()
    );
    assert_eq!(coalesced.sim.metrics.dropped, single.sim.metrics.dropped);
    assert_eq!(coalesced.sim.scale_ups, single.sim.scale_ups);
    assert_eq!(coalesced.sim.scale_downs, single.sim.scale_downs);
    assert!(
        coalesced.sim.events_processed < single.sim.events_processed,
        "coalescing must shrink the event count ({} vs {})",
        coalesced.sim.events_processed,
        single.sim.events_processed
    );
}

#[test]
fn coalesced_equals_single_step_mixed_workload() {
    // Mixed prompt/output lengths under an autoscaling policy: exercises
    // joins mid-window (transfer landings), scale-up/down, and drain.
    let trace = generate_family(TraceFamily::Mixed, 10.0, 75.0, 11);
    assert_modes_equivalent(PolicyKind::named("tokenscale"), &trace, RunOverrides::default());
}

#[test]
fn coalesced_equals_single_step_with_convertible_decoders() {
    // Convertible decoders interleave restricted chunked prefill with
    // decode; windows must yield to prefill admissions exactly like
    // single-stepping.
    let trace = generate_family(TraceFamily::AzureCode, 10.0, 75.0, 13);
    let ov = RunOverrides {
        convertibles: Some(2),
        ..Default::default()
    };
    assert_modes_equivalent(PolicyKind::named("tokenscale"), &trace, ov);
}

#[test]
fn coalesced_equals_single_step_for_baseline_policy() {
    // A baseline (no convertibles, different routing/scaling) as a second
    // independent control plane over the same mechanics.
    let trace = generate_family(TraceFamily::AzureConv, 10.0, 60.0, 17);
    assert_modes_equivalent(PolicyKind::named("distserve"), &trace, RunOverrides::default());
}
