//! Acceptance gate of the `sim::snapshot` subsystem: checkpoint → resume
//! must be **bit-identical** to an uninterrupted run, across the same
//! three scenario shapes the determinism gate
//! (`control_plane_equivalence.rs`) pins, for every stock policy.
//!
//! Four layers of equivalence are enforced:
//!
//! 1. **Resume** — a run interrupted mid-stream, serialized through the
//!    on-disk JSON text form, and resumed (same policy, state restored)
//!    reproduces the uninterrupted run's `SloReport`, completions, event
//!    count and GPU-seconds byte for byte.
//! 2. **Warm-start fork** — forking a cell policy from a shared warm-up
//!    prefix snapshot equals a straight-through cold run that switches
//!    policies at the same simulated time (no snapshot involved).
//! 3. **Cross-cell sharing** — a suite run that simulates the warm-up
//!    prefix once per scenario produces per-cell results identical to
//!    each cell computing its own (identical) prefix.
//! 4. **Stream resume (property)** — any generator+transform stack saved
//!    mid-stream and resumed by rebuild+fast-forward yields the exact
//!    arrival suffix, bit for bit.
//! 5. **Faults** — a run with an armed `FaultPlan` checkpointed
//!    mid-outage (degraded instances, a pending preemption deadline, a
//!    transfer brownout in flight) resumes bit-identically, and any
//!    random plan replayed from the same seed reproduces the SloReport
//!    and the failure ledger byte for byte (property).
//! 6. **Telemetry** — an observed run (`[scenarios.observe]`)
//!    checkpointed mid-capture, with span chains open and timeline
//!    accumulators partially filled, resumes to byte-identical exported
//!    artifacts (Perfetto JSON, span CSV, columnar timeline) and
//!    identical decision-record sample stamps.

use tokenscale::metrics::SloReport;
use tokenscale::report::{
    prepare_run, run_experiment, run_experiment_resumed, simulate_prefix, CheckpointSpec,
    ExperimentResult, PolicyKind, Scenario, Suite, TransformStep, Workload, WorkloadSpec,
};
use tokenscale::sim::{
    simulate_source, Action, ClusterView, ControlPlane, FaultKind, FaultPlan, FaultSchedule,
    FaultSpec, Role, Signal, SimSnapshot,
};
use tokenscale::trace::{fast_forward, BurstWindow, SessionModel, TraceFamily, TraceProfile};
use tokenscale::util::json::Json;
use tokenscale::util::prop::{check, Config};
use tokenscale::util::stats::Summary;

// ---------------------------------------------------- bit-equality kit

fn report_bits(r: &SloReport) -> Vec<u64> {
    let mut out = vec![
        r.n as u64,
        r.ttft_attainment.to_bits(),
        r.tpot_attainment.to_bits(),
        r.overall_attainment.to_bits(),
        r.avg_gpus.to_bits(),
        r.rejected_actions as u64,
    ];
    let mut push_summary = |s: &Summary| {
        out.push(s.count as u64);
        out.push(s.mean.to_bits());
        out.push(s.p50.to_bits());
        out.push(s.p90.to_bits());
        out.push(s.p99.to_bits());
        out.push(s.max.to_bits());
    };
    push_summary(&r.ttft);
    push_summary(&r.tpot);
    push_summary(&r.prefill_wait);
    push_summary(&r.queue_wait);
    // The failure ledger is part of the bit-equality contract too.
    out.extend([
        r.goodput_attainment.to_bits(),
        r.faults_injected as u64,
        r.lost_requests as u64,
        r.retried_requests as u64,
        r.abandoned_requests as u64,
        r.abandoned_retry_budget as u64,
        r.abandoned_starved as u64,
        r.wasted_prefill_tokens.to_bits(),
        r.transfer_retries as u64,
        r.transfer_aborts as u64,
        r.recovery_events as u64,
        r.recovery_mean_s.to_bits(),
        r.recovery_max_s.to_bits(),
        // Prefix-cache ledger: a resume that dropped or reordered warm
        // cache entries would change hits/saved tokens immediately.
        r.cache_hit_rate.to_bits(),
        r.saved_prefill_tokens.to_bits(),
    ]);
    out
}

/// The raw drop ledger, bit-exact (id, arrival, retries, reason).
fn abandoned_bits(res: &ExperimentResult) -> Vec<(u64, u64, u32, &'static str)> {
    res.sim
        .metrics
        .abandoned
        .iter()
        .map(|a| (a.id, a.arrival.to_bits(), a.retries, a.reason.label()))
        .collect()
}

fn completion_bits(res: &ExperimentResult) -> Vec<(u64, u64, u64, u64, u64)> {
    res.sim
        .metrics
        .completions
        .iter()
        .map(|c| {
            (
                c.id,
                c.arrival.to_bits(),
                c.ttft.to_bits(),
                c.tpot.to_bits(),
                c.finish.to_bits(),
            )
        })
        .collect()
}

fn assert_identical(label: &str, a: &ExperimentResult, b: &ExperimentResult) {
    assert_eq!(
        report_bits(&a.report),
        report_bits(&b.report),
        "{label}: SloReport must be byte-identical"
    );
    assert_eq!(
        completion_bits(a),
        completion_bits(b),
        "{label}: completions must be identical"
    );
    assert_eq!(
        a.sim.events_processed, b.sim.events_processed,
        "{label}: event counts"
    );
    assert_eq!(a.sim.scale_ups, b.sim.scale_ups, "{label}: scale-ups");
    assert_eq!(a.sim.scale_downs, b.sim.scale_downs, "{label}: scale-downs");
    assert_eq!(
        a.sim.metrics.gpu_seconds.to_bits(),
        b.sim.metrics.gpu_seconds.to_bits(),
        "{label}: GPU-seconds must be bit-identical"
    );
    assert_eq!(
        abandoned_bits(a),
        abandoned_bits(b),
        "{label}: abandoned-request ledgers must be identical"
    );
    assert!(a.report.n > 0, "{label}: scenario must complete requests");
}

/// Serialize a snapshot to its on-disk text form and parse it back — the
/// resume legs below always go through this, so the equivalence proven
/// is for the persisted artifact, not just the in-memory struct.
fn through_text(snap: &SimSnapshot) -> SimSnapshot {
    let text = snap.to_json().pretty();
    SimSnapshot::from_json(&Json::parse(&text).expect("snapshot text parses"))
        .expect("snapshot decodes")
}

/// For every policy cell: run cold to completion, then run interrupted —
/// checkpoint at `at_s` (through text), resume with a fresh policy
/// instance whose state is restored — and require bit equality.
fn scenario_resumes_bit_identically(scenario: &Scenario, at_s: f64) {
    for spec in scenario.experiment_specs().expect("specs compile") {
        let cold = run_experiment(&spec);
        let snap = simulate_prefix(&spec, spec.policy, at_s, 0.0, None)
            .unwrap_or_else(|e| panic!("{}: prefix failed: {e:#}", spec.label));
        let snap = through_text(&snap);
        let resumed = run_experiment_resumed(&spec, &snap, spec.policy, true)
            .unwrap_or_else(|e| panic!("{}: resume failed: {e:#}", spec.label));
        assert_identical(&spec.label, &cold, &resumed);
    }
}

// --------------------------- 1. resume == uninterrupted, all policies

/// Fig. 6/9-style policy-compare smoke (materialized shared trace).
#[test]
fn policy_compare_smoke_resumes_bit_identically() {
    let scenario = Scenario::new(
        "fig6-compare",
        "small-a100",
        WorkloadSpec::Synthetic {
            family: TraceFamily::Mixed,
            rps: 22.0,
            duration_s: 90.0,
            seed: 42,
        },
    )
    .all_baselines()
    .materialized();
    scenario_resumes_bit_identically(&scenario, 30.0);
}

/// `fig_longtrace`'s diurnal shape at smoke scale (streaming).
#[test]
fn longtrace_diurnal_smoke_resumes_bit_identically() {
    let (duration, rps, amp) = (150.0, 5.0, 0.35);
    let scenario = Scenario::new(
        "longtrace-diurnal",
        "large-a100",
        WorkloadSpec::Synthetic {
            family: TraceFamily::AzureConv,
            rps: rps * (1.0 + amp),
            duration_s: duration,
            seed: 101,
        },
    )
    .transform(TransformStep::Diurnal {
        amplitude: amp,
        period_s: duration,
        seed: 202,
    })
    .all_baselines();
    scenario_resumes_bit_identically(&scenario, 50.0);
}

/// `fig_longtrace`'s burst shape at smoke scale (streaming).
#[test]
fn longtrace_burst_smoke_resumes_bit_identically() {
    let duration = 150.0;
    let bursts: Vec<BurstWindow> = (0..3)
        .map(|i| BurstWindow::new(duration * (0.15 + 0.25 * i as f64), duration * 0.05, 3.0))
        .collect();
    let scenario = Scenario::new(
        "longtrace-burst",
        "large-a100",
        WorkloadSpec::Synthetic {
            family: TraceFamily::Mixed,
            rps: 5.0,
            duration_s: duration,
            seed: 303,
        },
    )
    .transform(TransformStep::Burst {
        windows: bursts,
        seed: 404,
    })
    .all_baselines();
    scenario_resumes_bit_identically(&scenario, 50.0);
}

/// The non-headline registry policies (ablations, deflection, static)
/// carry their own state shapes — cover their save/restore paths too.
#[test]
fn remaining_registry_policies_resume_bit_identically() {
    let scenario = Scenario::new(
        "extras",
        "small-a100",
        WorkloadSpec::Synthetic {
            family: TraceFamily::Mixed,
            rps: 10.0,
            duration_s: 60.0,
            seed: 77,
        },
    )
    .policies(&["b+p", "b+p+d", "deflect", "static"]);
    scenario_resumes_bit_identically(&scenario, 20.0);
}

/// The predictive planner family carries the richest state shape in the
/// registry — three forecasters, two correction EWMAs, six sliding
/// windows, the plan schedule, and (hybrid) the gateway — so gate both
/// policies through the same mid-run checkpoint kit. The planner knobs
/// are tightened so sampling *and* at least one re-plan (with a live
/// plan and correction observations) land inside the 60 s run and the
/// 20 s checkpoint straddles scheduled work on both sides.
#[test]
fn planner_family_resumes_bit_identically() {
    let scenario = Scenario::new(
        "planner-extras",
        "small-a100",
        WorkloadSpec::Synthetic {
            family: TraceFamily::Mixed,
            rps: 10.0,
            duration_s: 60.0,
            seed: 77,
        },
    )
    .policies(&["sla-planner", "sla-hybrid"])
    .with_planner(tokenscale::scaler::PlannerParams {
        sample_s: 2.0,
        interval_s: 10.0,
        period_s: 60.0,
        ..Default::default()
    });
    scenario_resumes_bit_identically(&scenario, 20.0);
}

/// An interrupted run with a decision-audit ring resumes with the ring
/// contents intact (total_seen continues, retained records survive).
#[test]
fn decision_log_survives_checkpoint_resume() {
    let mut scenario = Scenario::new(
        "audited",
        "small-a100",
        WorkloadSpec::Synthetic {
            family: TraceFamily::AzureConv,
            rps: 8.0,
            duration_s: 60.0,
            seed: 13,
        },
    )
    .policy("distserve");
    scenario.overrides.decision_log = 256;
    for spec in scenario.experiment_specs().unwrap() {
        let cold = run_experiment(&spec);
        let snap = through_text(&simulate_prefix(&spec, spec.policy, 20.0, 0.0, None).unwrap());
        let resumed = run_experiment_resumed(&spec, &snap, spec.policy, true).unwrap();
        let (a, b) = (
            cold.sim.decisions.as_ref().expect("ring enabled"),
            resumed.sim.decisions.as_ref().expect("ring enabled"),
        );
        assert_eq!(a.total_seen(), b.total_seen());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.t.to_bits(), y.t.to_bits());
            assert_eq!(x.action, y.action);
            assert_eq!(x.outcome, y.outcome);
        }
        assert_identical(&spec.label, &cold, &resumed);
    }
}

/// An interrupted *observed* run resumes to byte-identical telemetry:
/// the checkpoint at 25 s lands with span chains open (requests in
/// prefill/transfer/decode), a timeline arrival window partially
/// accumulated and sampled ids in flight, and every exported artifact of
/// the resumed run must equal the uninterrupted run's bytes — the
/// acceptance criterion for `ObsState::{to,from}_snapshot`.
#[test]
fn observed_run_resumes_with_identical_artifacts() {
    let mut scenario = Scenario::new(
        "observed",
        "small-a100",
        WorkloadSpec::Synthetic {
            family: TraceFamily::AzureConv,
            rps: 8.0,
            duration_s: 60.0,
            seed: 13,
        },
    )
    .policy("tokenscale")
    .with_observe(tokenscale::obs::ObserveConfig {
        sample_s: 2.0,
        span_sample_n: 2,
        seed: 5,
        sinks: vec![],
    });
    scenario.overrides.decision_log = 256;
    for spec in scenario.experiment_specs().unwrap() {
        let cold = run_experiment(&spec);
        let snap = through_text(&simulate_prefix(&spec, spec.policy, 25.0, 0.0, None).unwrap());
        let resumed = run_experiment_resumed(&spec, &snap, spec.policy, true).unwrap();
        let (a, b) = (
            cold.sim.obs.as_ref().expect("observe armed"),
            resumed.sim.obs.as_ref().expect("observe survives resume"),
        );
        a.spans.check_chains(true).expect("cold chains well-formed");
        assert!(!a.spans.events.is_empty(), "n=2 sampling must record spans");
        assert_eq!(
            tokenscale::obs::perfetto(&a.spans).pretty(),
            tokenscale::obs::perfetto(&b.spans).pretty(),
            "Perfetto artifact must be byte-identical across resume"
        );
        assert_eq!(
            tokenscale::obs::spans_csv(&a.spans),
            tokenscale::obs::spans_csv(&b.spans),
            "span CSV must be byte-identical across resume"
        );
        assert_eq!(
            a.timeline.to_json().pretty(),
            b.timeline.to_json().pretty(),
            "timeline artifact must be byte-identical across resume"
        );
        // Decision-record correlation survives too: every retained record
        // carries the same nearest-sample stamp on both legs.
        let (da, db) = (
            cold.sim.decisions.as_ref().expect("ring enabled"),
            resumed.sim.decisions.as_ref().expect("ring enabled"),
        );
        assert_eq!(da.len(), db.len());
        for (x, y) in da.iter().zip(db.iter()) {
            assert_eq!(x.sample, y.sample, "sample stamp at t={}", x.t);
        }
        assert!(
            da.iter().any(|r| r.sample.is_some()),
            "records must correlate with timeline samples"
        );
        assert_identical(&spec.label, &cold, &resumed);
    }
}

// ----------------------- 2. warm-start fork == switch-policy cold run

/// Delegates to the warm-up driver until `at` (inclusive), then to the
/// cell policy — the no-snapshot reference for the warm-start fork.
struct SwitchPolicy {
    driver: Box<dyn ControlPlane>,
    cell: Box<dyn ControlPlane>,
    at: f64,
    now: f64,
}

impl ControlPlane for SwitchPolicy {
    fn name(&self) -> &str {
        "switch"
    }

    fn on_signal(
        &mut self,
        now: f64,
        signal: Signal<'_>,
        view: &ClusterView<'_>,
        actions: &mut Vec<Action>,
    ) {
        self.now = now;
        if now <= self.at {
            self.driver.on_signal(now, signal, view, actions);
        } else {
            self.cell.on_signal(now, signal, view, actions);
        }
    }

    fn live_scaling(&self) -> bool {
        if self.now <= self.at {
            self.driver.live_scaling()
        } else {
            self.cell.live_scaling()
        }
    }
}

#[test]
fn warm_start_fork_matches_switch_policy_cold_run() {
    let warm_s = 30.0;
    let driver_name = "tokenscale";
    // blitzscale exercises the live_scaling handover too.
    for cell_name in ["distserve", "blitzscale", "tokenscale"] {
        let base = Scenario::new(
            "fork",
            "small-a100",
            WorkloadSpec::Synthetic {
                family: TraceFamily::AzureConv,
                rps: 10.0,
                duration_s: 90.0,
                seed: 21,
            },
        )
        .policy(cell_name);
        let mut warm_sc = base.clone();
        warm_sc.checkpoint = Some(CheckpointSpec {
            warm_start_s: warm_s,
            policy: driver_name.into(),
            every_s: 0.0,
        });
        let spec = warm_sc.experiment_specs().unwrap().remove(0);
        // Warm leg: prefix + snapshot + fork (computed inside).
        let warm = run_experiment(&spec);

        // Cold leg: one straight-through run, switching policies at the
        // boundary, on the driver's cluster/sim config (which is what
        // built the snapshot's fleet).
        let Workload::Streaming(factory) = &spec.workload else {
            panic!("scenario compiles to a streaming workload");
        };
        let mut src = factory();
        let profile: TraceProfile = src.profile();
        let driver_kind = PolicyKind::named(driver_name);
        let (sim_cfg, cluster_cfg, driver_built) =
            prepare_run(&spec.deployment, driver_kind, &profile, &spec.overrides);
        let (_, _, cell_built) =
            prepare_run(&spec.deployment, spec.policy, &profile, &spec.overrides);
        let slo = sim_cfg.slo;
        let mut switch = SwitchPolicy {
            driver: driver_built.plane,
            cell: cell_built.plane,
            at: warm_s,
            now: 0.0,
        };
        let sim = simulate_source(sim_cfg, cluster_cfg, &mut switch, src.as_mut());
        let report = sim.metrics.report(&slo, spec.overrides.warmup_s);
        let cold = ExperimentResult {
            policy: spec.policy,
            report,
            sim,
            label: spec.label.clone(),
            wall_s: 0.0,
        };
        assert_identical(&format!("fork/{cell_name}"), &cold, &warm);
    }
}

// --------------------------- 3. suite-shared prefix == per-cell prefix

#[test]
fn suite_shares_the_prefix_and_matches_unshared_cells() {
    let scenario = Scenario::new(
        "warmed",
        "small-a100",
        WorkloadSpec::Synthetic {
            family: TraceFamily::AzureConv,
            rps: 8.0,
            duration_s: 80.0,
            seed: 7,
        },
    )
    .policies(&["distserve", "static"])
    .with_checkpoint(CheckpointSpec {
        warm_start_s: 25.0,
        policy: "static".into(),
        every_s: 0.0,
    });
    let suite = Suite::new("warmtest", "warm-start equivalence fixture").scenario(scenario.clone());
    let run = suite.run().expect("suite runs");

    // Amortization accounting: one prefix, two forked cells.
    assert_eq!(run.warm_start.len(), 1);
    let w = &run.warm_start[0];
    assert_eq!(w.scenario, "warmed");
    assert_eq!(w.cells, 2);
    assert_eq!(w.warm_start_s, 25.0);
    assert!(w.prefix_wall_s > 0.0);
    let doc = run.to_json();
    assert!(
        doc.get_path(&["warm_start", "warmed", "prefix_wall_s"]).is_some(),
        "bench JSON reports the warm-start amortization"
    );

    // Each suite cell (shared snapshot) equals the same cell run alone
    // (which computes its own prefix).
    for spec in scenario.experiment_specs().unwrap() {
        let solo = run_experiment(&spec);
        let shared = run
            .result("warmed", spec.policy.name())
            .expect("cell present");
        assert_identical(&spec.label, &solo, shared);
    }
}

// -------------------------------- 4. stream resume suffix (property)

#[test]
fn any_source_stack_resumes_to_the_identical_suffix() {
    let families = [
        TraceFamily::AzureConv,
        TraceFamily::AzureCode,
        TraceFamily::BurstGpt1,
        TraceFamily::BurstGpt2,
        TraceFamily::Mixed,
    ];
    check(Config::named("source-resume-suffix").cases(48), |rng| {
        let family = families[rng.below(families.len() as u64) as usize];
        let duration = rng.range_f64(30.0, 80.0);
        let workload = WorkloadSpec::Synthetic {
            family,
            rps: rng.range_f64(2.0, 8.0),
            duration_s: duration,
            seed: rng.next_u64(),
        };
        let mut sc = Scenario::new("prop", "small-a100", workload).policy("static");
        for _ in 0..rng.below(4) {
            let step = match rng.below(5) {
                0 => TransformStep::Window {
                    t0: rng.range_f64(0.0, duration * 0.2),
                    t1: rng.range_f64(duration * 0.5, duration),
                },
                1 => TransformStep::RateScale {
                    factor: rng.range_f64(0.5, 2.0),
                },
                2 => TransformStep::Diurnal {
                    amplitude: rng.range_f64(0.1, 0.6),
                    period_s: duration,
                    seed: rng.next_u64(),
                },
                3 => TransformStep::Burst {
                    windows: vec![BurstWindow::new(
                        rng.range_f64(0.0, duration * 0.5),
                        rng.range_f64(1.0, duration * 0.3),
                        rng.range_f64(1.5, 3.0),
                    )],
                    seed: rng.next_u64(),
                },
                _ => TransformStep::Resample {
                    target_rps: rng.range_f64(2.0, 10.0),
                    seed: rng.next_u64(),
                },
            };
            sc = sc.transform(step);
        }
        let factory = sc.source_factory().expect("stack builds");

        // Pull K arrivals from stream A (the "interrupted" run)...
        let mut a = factory();
        let mut pulled = 0u64;
        let k_target = rng.below(200);
        while pulled < k_target {
            if a.next_request().is_none() {
                break;
            }
            pulled += 1;
        }
        // ...then rebuild + fast-forward a fresh copy (the resume path).
        let mut b = factory();
        assert_eq!(fast_forward(b.as_mut(), pulled), pulled);
        // The entire remaining suffix must match bit for bit.
        let mut remaining = 0usize;
        loop {
            match (a.next_request(), b.next_request()) {
                (None, None) => break,
                (x, y) => {
                    let x = x.expect("original stream ended before resumed copy");
                    let y = y.expect("resumed copy ended before original stream");
                    assert_eq!(x.id, y.id);
                    assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
                    assert_eq!(x.input_tokens, y.input_tokens);
                    assert_eq!(x.output_tokens, y.output_tokens);
                    remaining += 1;
                }
            }
        }
        // Guard against vacuous cases: with K capped well below the
        // stream length at these rates, most cases must have a suffix.
        let _ = remaining;
    });
}

// ----------------------- 5. faults: mid-outage resume + replay (prop)

/// A chaos plan whose every mechanism is mid-flight at the checkpoint
/// time (t = 40): a crash already fired, a preemption warned but not yet
/// killed, a degrade window and a transfer brownout both spanning t = 40.
fn chaos_scenario() -> Scenario {
    let plan = FaultPlan {
        seed: 616,
        entries: vec![
            FaultSpec {
                kind: FaultKind::Crash,
                role: Some(Role::Decoder),
                instance_index: None,
                schedule: FaultSchedule::At { t: 25.0 },
            },
            // Warned at 35, force-killed at 47: the kill event is
            // pending in the queue at checkpoint time.
            FaultSpec {
                kind: FaultKind::Preempt { warning_s: 12.0 },
                role: Some(Role::Decoder),
                instance_index: None,
                schedule: FaultSchedule::At { t: 35.0 },
            },
            // Degraded 30–60: the perf_factor must survive the snapshot.
            FaultSpec {
                kind: FaultKind::Degrade {
                    factor: 2.5,
                    duration_s: 30.0,
                },
                role: Some(Role::Prefiller),
                instance_index: Some(0),
                schedule: FaultSchedule::At { t: 30.0 },
            },
            // Brownout 30–55: doomed transfers and their backoff clocks
            // are in flight at checkpoint time.
            FaultSpec {
                kind: FaultKind::Transfer {
                    loss_prob: 0.4,
                    stall_s: 1.5,
                    max_retries: 2,
                    duration_s: 25.0,
                },
                role: None,
                instance_index: None,
                schedule: FaultSchedule::At { t: 30.0 },
            },
        ],
    };
    Scenario::new(
        "chaos-resume",
        "small-a100",
        WorkloadSpec::Synthetic {
            family: TraceFamily::Mixed,
            rps: 10.0,
            duration_s: 90.0,
            seed: 515,
        },
    )
    .all_baselines()
    .with_faults(plan)
}

/// A checkpoint taken in the middle of an outage — degraded instances,
/// a pending preemption deadline, a live transfer brownout, and the
/// failure ledger partially filled — must resume bit-identically for
/// every stock policy. (`report_bits` pins the full ledger, so goodput,
/// wasted prefill tokens and recovery times are covered.)
#[test]
fn chaos_run_resumes_bit_identically_mid_outage() {
    let scenario = chaos_scenario();
    // Guard against vacuity: the plan must actually bite.
    let spec = scenario.experiment_specs().unwrap().remove(0);
    let cold = run_experiment(&spec);
    assert!(
        cold.report.faults_injected >= 4,
        "chaos plan must fire all four entries (got {})",
        cold.report.faults_injected
    );
    assert!(
        cold.report.lost_requests > 0
            || cold.report.retried_requests > 0
            || cold.report.transfer_retries > 0,
        "chaos plan must displace at least some work"
    );
    scenario_resumes_bit_identically(&scenario, 40.0);
}

// ---------------------- 6. scheduler: mid-wheel checkpoints (near+far)

/// A checkpoint taken while the timing-wheel scheduler is mid-span —
/// events pending both inside the 4 s near-wheel window (4096 ticks at
/// 1024/s) and beyond it in the far heap — must dump and rebuild
/// bit-identically. A materialized trace keeps every future arrival
/// queued up front, and a crash armed at t = 70 pins a far-heap entry
/// ~40 s past the checkpoint, so the dump provably straddles the span.
#[test]
fn mid_wheel_checkpoint_spans_near_and_far_horizons() {
    let plan = FaultPlan {
        seed: 99,
        entries: vec![FaultSpec {
            kind: FaultKind::Crash,
            role: Some(Role::Decoder),
            instance_index: None,
            schedule: FaultSchedule::At { t: 70.0 },
        }],
    };
    let scenario = Scenario::new(
        "mid-wheel",
        "small-a100",
        WorkloadSpec::Synthetic {
            family: TraceFamily::Mixed,
            rps: 15.0,
            duration_s: 90.0,
            seed: 4242,
        },
    )
    .all_baselines()
    .materialized()
    .with_faults(plan);

    // Non-vacuity guard: the snapshot's event dump must hold entries on
    // both sides of the wheel span around the checkpoint time (±0.5 s
    // margin keeps the assertion clear of the tick-quantized boundary).
    let spec = scenario.experiment_specs().unwrap().remove(0);
    let snap = simulate_prefix(&spec, spec.policy, 30.0, 0.0, None).unwrap();
    let times: Vec<f64> = snap
        .engine
        .get("events")
        .and_then(|e| e.get("entries"))
        .and_then(Json::as_arr)
        .expect("snapshot carries the event dump")
        .iter()
        .map(|e| e.get("t").and_then(Json::as_f64_bits).expect("entry time"))
        .collect();
    let near = times.iter().filter(|t| **t < snap.t + 3.5).count();
    let far = times.iter().filter(|t| **t > snap.t + 4.5).count();
    assert!(near > 0, "no pending events inside the near-wheel window");
    assert!(far > 0, "no pending events beyond the wheel span (far heap)");

    scenario_resumes_bit_identically(&scenario, 30.0);
}

// ------------------- 7. sketch-mode metrics: exact parity + O(1) resume

/// Sketch-mode runs (`retain_completions = false`) must agree with
/// retained-mode runs on every exactly-computed report field —
/// attainments, goodput, GPU accounting, distribution counts and maxima
/// — while keeping no per-completion state in memory or in checkpoints;
/// percentiles must stay within the log-bucket quantization bound. An
/// interrupted sketch-mode run must also resume bit-identically, with
/// the mode restored from snapshot content.
#[test]
fn sketch_mode_matches_retained_and_resumes_bit_identically() {
    let base = Scenario::new(
        "sketch-parity",
        "small-a100",
        WorkloadSpec::Synthetic {
            family: TraceFamily::Mixed,
            rps: 18.0,
            duration_s: 90.0,
            seed: 2718,
        },
    )
    .all_baselines();
    let mut sketch_sc = base.clone();
    sketch_sc.overrides.retain_completions = false;

    let retained_specs = base.experiment_specs().unwrap();
    let sketch_specs = sketch_sc.experiment_specs().unwrap();
    for (rs, ss) in retained_specs.iter().zip(&sketch_specs) {
        let a = run_experiment(rs);
        let b = run_experiment(ss);
        let label = &rs.label;
        assert!(a.report.n > 0, "{label}: scenario must complete requests");
        assert_eq!(a.report.n, b.report.n, "{label}: n");
        for (name, x, y) in [
            ("ttft_attainment", a.report.ttft_attainment, b.report.ttft_attainment),
            ("tpot_attainment", a.report.tpot_attainment, b.report.tpot_attainment),
            ("overall_attainment", a.report.overall_attainment, b.report.overall_attainment),
            ("goodput_attainment", a.report.goodput_attainment, b.report.goodput_attainment),
            ("avg_gpus", a.report.avg_gpus, b.report.avg_gpus),
        ] {
            assert_eq!(x.to_bits(), y.to_bits(), "{label}: {name}");
        }
        assert_eq!(a.report.rejected_actions, b.report.rejected_actions);
        assert_eq!(a.report.abandoned_requests, b.report.abandoned_requests);
        assert_eq!(
            a.sim.metrics.gpu_seconds.to_bits(),
            b.sim.metrics.gpu_seconds.to_bits(),
            "{label}: GPU-seconds"
        );
        assert_eq!(a.sim.events_processed, b.sim.events_processed);
        // Distribution counts and maxima are exact in sketch mode; means
        // and percentiles are not compared here (summation order and
        // bucket quantization — bounded below and in metrics::sketch).
        for (name, x, y) in [
            ("ttft", &a.report.ttft, &b.report.ttft),
            ("tpot", &a.report.tpot, &b.report.tpot),
            ("prefill_wait", &a.report.prefill_wait, &b.report.prefill_wait),
            ("queue_wait", &a.report.queue_wait, &b.report.queue_wait),
        ] {
            assert_eq!(x.count, y.count, "{label}: {name}.count");
            assert_eq!(x.max.to_bits(), y.max.to_bits(), "{label}: {name}.max");
        }
        // Percentile bound, checked against the retained run's exact
        // order statistics: the sketch reports the log-bucket
        // representative of the nearest-rank element, which sits within
        // 2.3% of it (metrics::sketch).
        let mut ttfts: Vec<f64> = a
            .sim
            .metrics
            .completions
            .iter()
            .filter(|c| c.arrival >= rs.overrides.warmup_s)
            .map(|c| c.ttft)
            .collect();
        ttfts.sort_by(f64::total_cmp);
        for (q, approx) in [
            (50.0, b.report.ttft.p50),
            (90.0, b.report.ttft.p90),
            (99.0, b.report.ttft.p99),
        ] {
            let exact = ttfts[((q / 100.0) * (ttfts.len() - 1) as f64) as usize];
            assert!(
                (approx - exact).abs() <= exact * 0.024 + 1e-12,
                "{label}: ttft p{q} {approx} strays from nearest-rank {exact}"
            );
        }
        // O(1) memory: sketch mode retains nothing per-completion...
        assert!(b.sim.metrics.completions.is_empty());
        assert!(b.sim.metrics.prefill_waits.is_empty());
        assert!(b.sim.metrics.queue_waits.is_empty());
    }

    // ...and neither do its checkpoints: the metrics blob carries the
    // fixed-size sketch instead of the completion list.
    let spec = sketch_specs.into_iter().next().unwrap();
    let snap = simulate_prefix(&spec, spec.policy, 45.0, 0.0, None).unwrap();
    let metrics = snap.engine.get("metrics").expect("metrics blob");
    assert!(metrics.get("sketch").is_some(), "sketch blob in checkpoint");
    assert_eq!(
        metrics
            .get("completions")
            .and_then(Json::as_arr)
            .map(<[Json]>::len),
        Some(0),
        "sketch-mode checkpoints must not retain completions"
    );

    // Interrupted sketch-mode runs resume bit-identically (mode restored
    // from the snapshot, percentiles and all).
    scenario_resumes_bit_identically(&sketch_sc, 30.0);
}

// ------------------- 8. prefix cache: warm mid-session checkpoints

/// A checkpoint taken mid-session — warm prefix-cache entries live on
/// instances, follow-up turns still pending in their sessions — must
/// resume bit-identically for every router in the cache-aware family.
/// `report_bits` pins the cache ledger (hit rate, saved prefill tokens),
/// so a resume that dropped, reordered or re-aged warm entries would
/// diverge on the first follow-up turn after the checkpoint.
#[test]
fn warm_cache_mid_session_resumes_bit_identically_across_routers() {
    let mut scenario = Scenario::new(
        "kv-resume",
        "small-a100",
        WorkloadSpec::Synthetic {
            family: TraceFamily::AzureConv,
            rps: 4.0,
            duration_s: 90.0,
            seed: 808,
        },
    )
    .with_sessions(SessionModel::new(5.0, 6.0))
    .policies(&["kv-router", "kv-router-rps", "random-router", "round-robin-router"]);
    scenario.overrides.kv_capacity_tokens = Some(300_000);

    // Non-vacuity: the cache must actually be hot. The kv-router cell
    // (first policy) must score warm hits, and the snapshot taken at the
    // checkpoint time must carry live cache entries on some instance.
    let spec = scenario.experiment_specs().unwrap().remove(0);
    let cold = run_experiment(&spec);
    assert!(
        cold.report.cache_hit_rate > 0.0,
        "kv-router cell produced no warm hits — fixture is vacuous"
    );
    assert!(cold.report.saved_prefill_tokens > 0.0, "no prefill saved");
    let snap = simulate_prefix(&spec, spec.policy, 45.0, 0.0, None).unwrap();
    let warm_entries: usize = snap
        .engine
        .get("cluster")
        .and_then(|c| c.get("slots"))
        .and_then(Json::as_arr)
        .expect("snapshot carries the cluster slots")
        .iter()
        .filter_map(|s| s.get("inst"))
        .filter_map(|i| i.get("kvcache"))
        .filter_map(|k| k.get("entries"))
        .filter_map(Json::as_arr)
        .map(<[Json]>::len)
        .sum();
    assert!(
        warm_entries > 0,
        "mid-session checkpoint must hold warm cache entries"
    );

    scenario_resumes_bit_identically(&scenario, 45.0);
}

/// Any fault plan replayed from the same seed yields a byte-identical
/// SloReport, completion list and abandoned ledger — the determinism
/// contract `docs/faults.md` promises, across the policy registry.
#[test]
fn any_fault_plan_replays_bit_identically() {
    let policies = [
        "tokenscale",
        "aibrix",
        "blitzscale",
        "distserve",
        "b+p",
        "deflect",
        "static",
    ];
    check(Config::named("fault-plan-replay").cases(12), |rng| {
        let duration = rng.range_f64(40.0, 70.0);
        let mut entries = Vec::new();
        for _ in 0..1 + rng.below(3) {
            let kind = match rng.below(4) {
                0 => FaultKind::Crash,
                1 => FaultKind::Preempt {
                    warning_s: rng.range_f64(2.0, 15.0),
                },
                2 => FaultKind::Degrade {
                    factor: rng.range_f64(1.5, 4.0),
                    duration_s: rng.range_f64(10.0, 40.0),
                },
                _ => FaultKind::Transfer {
                    loss_prob: rng.range_f64(0.1, 0.6),
                    stall_s: rng.range_f64(0.5, 3.0),
                    max_retries: 1 + rng.below(3) as u32,
                    duration_s: rng.range_f64(10.0, 40.0),
                },
            };
            let role = match rng.below(3) {
                0 => None,
                1 => Some(Role::Prefiller),
                _ => Some(Role::Decoder),
            };
            let schedule = match rng.below(3) {
                0 => FaultSchedule::At {
                    t: rng.range_f64(5.0, duration * 0.8),
                },
                1 => FaultSchedule::Every {
                    period_s: rng.range_f64(20.0, 40.0),
                    from_s: rng.range_f64(5.0, 20.0),
                    until_s: duration,
                },
                _ => FaultSchedule::Poisson {
                    rate_per_s: rng.range_f64(0.01, 0.05),
                    from_s: 5.0,
                    until_s: duration,
                    count: 2,
                },
            };
            entries.push(FaultSpec {
                kind,
                role,
                instance_index: None,
                schedule,
            });
        }
        let plan = FaultPlan {
            seed: rng.next_u64(),
            entries,
        };
        plan.validate().expect("generated plan is valid");
        let policy = policies[rng.below(policies.len() as u64) as usize];
        let sc = Scenario::new(
            "fault-replay",
            "small-a100",
            WorkloadSpec::Synthetic {
                family: TraceFamily::Mixed,
                rps: rng.range_f64(4.0, 9.0),
                duration_s: duration,
                seed: rng.next_u64(),
            },
        )
        .policy(policy)
        .with_faults(plan);
        let spec = sc.experiment_specs().expect("specs compile").remove(0);
        let (a, b) = (run_experiment(&spec), run_experiment(&spec));
        assert_identical(&format!("replay/{policy}"), &a, &b);
    });
}
