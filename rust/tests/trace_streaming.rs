//! Streaming arrival-pipeline safety nets:
//!
//! 1. **Generator equivalence** — for every `TraceSpec` family, the lazy
//!    [`SpecSource`]/[`MixedSource`] streams must yield the byte-identical
//!    request sequence the pre-streaming eager generators produced. The
//!    oracle below is a verbatim copy of those eager implementations
//!    (materialize-then-sort), so any drift in rng draw order, episode
//!    accounting, or merge tie-breaking fails loudly.
//! 2. **Replay round trips** — replay → materialize → replay must be
//!    lossless in both CSV and JSONL, including across formats.
//! 3. **Resample regression** — the duplication path must keep arrivals
//!    time-sorted with ids re-sequenced in arrival order.
//! 4. **Engine equivalence** — driving the simulator from a live stream
//!    must reproduce the preloaded-trace run event for event.

use std::sync::Arc;
use tokenscale::report::{deployment, run_experiment, ExperimentSpec, PolicyKind};
use tokenscale::trace::{
    base_families, generate, generate_mixed, materialize, replay, ArrivalSource, MixedSource,
    SourceExt, SourceFactory, SpecSource, Trace, TraceFamily, TraceProfile, TraceSpec,
};
use tokenscale::util::rng::Pcg64;
use tokenscale::workload::Request;

// ---------------------------------------------------------------- oracle
//
// Verbatim port of the eager generators that predate the streaming
// pipeline (trace/gen.rs as of PR 1). Kept here, not in the library, so
// the production path stays single-implementation.

fn oracle_sample_len(rng: &mut Pcg64, d: &tokenscale::trace::LenDist) -> usize {
    (rng.lognormal(d.mu, d.sigma).round() as usize).clamp(d.min, d.max)
}

fn oracle_generate(spec: &TraceSpec, seed: u64) -> Trace {
    let mut rng = Pcg64::new(seed);
    let mut arrivals_rng = rng.fork();
    let mut len_rng = rng.fork();
    let mut episode_rng = rng.fork();

    let bf = &spec.burst;
    let r_stable = spec.rps / (bf.time_fraction * bf.rate_factor + (1.0 - bf.time_fraction));
    let r_burst = r_stable * bf.rate_factor;
    let mean_stable_gap = if bf.time_fraction > 0.0 {
        bf.mean_len_s * (1.0 - bf.time_fraction) / bf.time_fraction
    } else {
        f64::INFINITY
    };

    let mut requests = Vec::new();
    let mut t = 0.0f64;
    let mut in_burst = false;
    let mut phase_end = if mean_stable_gap.is_finite() {
        episode_rng.exponential(1.0 / mean_stable_gap)
    } else {
        f64::INFINITY
    };
    let mut id = 0u64;

    while t < spec.duration_s {
        while t >= phase_end {
            in_burst = !in_burst;
            let mean = if in_burst { bf.mean_len_s } else { mean_stable_gap };
            phase_end += episode_rng.exponential(1.0 / mean);
        }
        let diurnal =
            1.0 + spec.diurnal_amplitude * (2.0 * std::f64::consts::PI * t / spec.diurnal_period_s).sin();
        let rate = (if in_burst { r_burst } else { r_stable }) * diurnal.max(0.05);
        let k = spec.arrival_shape;
        let gap = arrivals_rng.gamma(k, 1.0 / (k * rate));
        t += gap;
        if t >= spec.duration_s {
            break;
        }
        let input = oracle_sample_len(&mut len_rng, &spec.input_len);
        let output = oracle_sample_len(&mut len_rng, &spec.output_len);
        requests.push(Request::new(id, t, input, output));
        id += 1;
    }

    Trace {
        name: spec.name.clone(),
        duration_s: spec.duration_s,
        requests,
    }
}

fn oracle_generate_mixed(total_rps: f64, duration_s: f64, seed: u64) -> Trace {
    let per = total_rps / 4.0;
    let mut requests = Vec::new();
    for (i, fam) in base_families().into_iter().enumerate() {
        let sub = oracle_generate(&fam.spec(per, duration_s), seed.wrapping_add(i as u64 * 7919));
        requests.extend(sub.requests);
    }
    requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    for (i, r) in requests.iter_mut().enumerate() {
        r.id = i as u64;
    }
    Trace {
        name: "mixed".into(),
        duration_s,
        requests,
    }
}

// ----------------------------------------------------------- equivalence

#[test]
fn streaming_generator_matches_eager_oracle_for_every_family() {
    for family in base_families() {
        for seed in [1u64, 7, 42, 1234] {
            let spec = family.spec(14.0, 180.0);
            let eager = oracle_generate(&spec, seed);
            let streamed = materialize(&mut SpecSource::new(spec.clone(), seed));
            assert!(!eager.requests.is_empty(), "{family:?} produced nothing");
            assert_eq!(
                streamed.requests, eager.requests,
                "{family:?} seed {seed}: streaming sequence must be byte-identical"
            );
            assert_eq!(streamed.duration_s, eager.duration_s);
            assert_eq!(streamed.name, eager.name);
            // The library's `generate` is the same stream drained.
            assert_eq!(generate(&spec, seed).requests, eager.requests);
        }
    }
}

#[test]
fn streaming_mixed_matches_eager_merge_oracle() {
    for seed in [5u64, 99] {
        let eager = oracle_generate_mixed(20.0, 150.0, seed);
        let streamed = materialize(&mut MixedSource::new(20.0, 150.0, seed));
        assert_eq!(
            streamed.requests, eager.requests,
            "seed {seed}: 4-way merge must reproduce the stable sort"
        );
        assert_eq!(generate_mixed(20.0, 150.0, seed).requests, eager.requests);
    }
}

#[test]
fn zero_duration_spec_yields_empty_stream() {
    let spec = TraceFamily::AzureConv.spec(10.0, 0.0);
    let mut src = SpecSource::new(spec, 3);
    assert!(src.next_request().is_none());
    assert!(src.next_request().is_none(), "exhausted source stays exhausted");
}

// ---------------------------------------------------------- replay trips

#[test]
fn replay_materialize_replay_round_trip_is_lossless() {
    for family in [TraceFamily::AzureConv, TraceFamily::BurstGpt2] {
        let t = generate(&family.spec(6.0, 90.0), 11);

        let csv = replay::to_csv(&t);
        let from_csv = replay::parse_csv(&csv, &t.name).unwrap();
        assert_eq!(from_csv.requests, t.requests, "{family:?} csv");
        assert_eq!(from_csv.duration_s, t.duration_s);
        assert_eq!(replay::to_csv(&from_csv), csv, "csv canonical form stable");

        let jsonl = replay::to_jsonl(&t);
        let from_jsonl = replay::parse_jsonl(&jsonl, &t.name).unwrap();
        assert_eq!(from_jsonl.requests, t.requests, "{family:?} jsonl");
        assert_eq!(from_jsonl.duration_s, t.duration_s);
        assert_eq!(replay::to_jsonl(&from_jsonl), jsonl);

        // Cross-format: csv -> jsonl -> csv ends where it started.
        let cross = replay::parse_jsonl(&replay::to_jsonl(&from_csv), &t.name).unwrap();
        assert_eq!(replay::to_csv(&cross), csv);
    }
}

#[test]
fn bundled_example_traces_load_and_stream() {
    for rel in ["examples/traces/azure_conv_sample.csv", "examples/traces/burstgpt_sample.jsonl"] {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(rel);
        let t = replay::load_path(&path).unwrap_or_else(|e| panic!("loading {rel}: {e}"));
        assert!(t.requests.len() >= 150, "{rel}: {} rows", t.requests.len());
        assert!(t.duration_s > 0.0);
        for w in t.requests.windows(2) {
            assert!(w[0].arrival <= w[1].arrival, "{rel} must be time-sorted");
        }
        let mut src = tokenscale::trace::OwnedTraceSource::new(t.clone());
        let back = materialize(&mut src);
        assert_eq!(back.requests, t.requests);
    }
}

// ------------------------------------------------------ resample regress

#[test]
fn resample_duplication_sorts_and_resequences_ids() {
    let t = generate(&TraceFamily::AzureCode.spec(6.0, 150.0), 17);
    let mut rng = Pcg64::new(23);
    let up = t.resample_to_rps(20.0, &mut rng);
    assert!((up.avg_rps() - 20.0).abs() < 3.0, "rps={}", up.avg_rps());

    // Sort-and-compare: the sequence must already be arrival-sorted.
    let mut sorted = up.requests.clone();
    sorted.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    assert_eq!(sorted, up.requests);
    for (i, r) in up.requests.iter().enumerate() {
        assert_eq!(r.id, i as u64);
    }

    // Deterministic re-derivation from the caller's rng state.
    let mut rng2 = Pcg64::new(23);
    assert_eq!(t.resample_to_rps(20.0, &mut rng2).requests, up.requests);
}

// -------------------------------------------------------- engine streams

#[test]
fn streamed_run_matches_preloaded_run_for_every_policy() {
    let spec = TraceFamily::AzureConv.spec(8.0, 60.0);
    let seed = 31;
    let trace = generate(&spec, seed);
    let dep = deployment("small-a100").unwrap();
    // Use the measured profile on both sides so the only difference is
    // preloaded-vs-streamed arrival delivery.
    let profile = TraceProfile::of_trace(&trace);
    for policy in [PolicyKind::named("tokenscale"), PolicyKind::named("distserve")] {
        let preloaded = run_experiment(&ExperimentSpec::shared(&dep, policy, &trace));
        let stream_spec = spec.clone();
        let factory: SourceFactory =
            Arc::new(move || SpecSource::new(stream_spec.clone(), seed).boxed());
        let streamed =
            run_experiment(&ExperimentSpec::streaming(&dep, policy, factory).with_profile(profile));
        assert_eq!(
            preloaded.sim.events_processed, streamed.sim.events_processed,
            "{}: event counts must match",
            policy.name()
        );
        let key = |r: &tokenscale::report::ExperimentResult| {
            let mut v: Vec<(u64, f64, f64, f64)> = r
                .sim
                .metrics
                .completions
                .iter()
                .map(|c| (c.id, c.ttft, c.tpot, c.finish))
                .collect();
            v.sort_by(|a, b| a.0.cmp(&b.0));
            v
        };
        assert_eq!(key(&preloaded), key(&streamed), "{}", policy.name());
        assert_eq!(preloaded.report.n, streamed.report.n);
        assert_eq!(
            preloaded.report.overall_attainment,
            streamed.report.overall_attainment
        );
        assert_eq!(preloaded.sim.metrics.gpu_seconds, streamed.sim.metrics.gpu_seconds);
        // The stream was consumed exactly once and fully.
        assert_eq!(streamed.sim.metrics.arrivals, trace.requests.len());
    }
}
