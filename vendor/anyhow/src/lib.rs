//! Offline stand-in for the `anyhow` crate.
//!
//! The container image has no crates.io access, so this path dependency
//! provides the subset of anyhow's surface the codebase uses: the opaque
//! [`Error`] type, the [`Result`] alias, the `anyhow!` / `bail!` /
//! `ensure!` macros, and the blanket `From<E: std::error::Error>` that
//! makes `?` work. Swap it for the real crate by editing the root
//! Cargo.toml if a registry is available.

use std::error::Error as StdError;
use std::fmt;

/// Opaque, message-carrying error. Like the real `anyhow::Error`, it does
/// **not** implement `std::error::Error` itself, which keeps the blanket
/// `From` impl below coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_and_conversions() {
        fn inner(fail: bool) -> crate::Result<u32> {
            crate::ensure!(!fail, "failed with {}", 42);
            let n: u32 = "7".parse()?; // ParseIntError -> Error via blanket From
            Ok(n)
        }
        assert_eq!(inner(false).unwrap(), 7);
        let e = inner(true).unwrap_err();
        assert_eq!(e.to_string(), "failed with 42");
        let e2: crate::Error = crate::anyhow!("x={}", 1);
        assert_eq!(format!("{e2:?}"), "x=1");
    }
}
